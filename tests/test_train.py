"""Training substrate: optimizer, train loop convergence, checkpointing,
fault recovery, serving, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import available_steps, latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, reduced
from repro.data import SyntheticConfig, batch_for_step, prefetch_batches
from repro.models import build_model
from repro.runtime import CheckpointManager, run_with_recovery
from repro.serve import ServeConfig, generate
from repro.train import (
    AdamWConfig,
    TrainConfig,
    adamw_init,
    adamw_update,
    global_norm,
    init_train_state,
    make_train_step,
    warmup_cosine,
)

KEY = jax.random.PRNGKey(0)


def _tiny_api(name="internlm2-1.8b", **kw):
    cfg = reduced(get_config(name), **kw)
    return build_model(cfg)


class TestOptimizer:
    def test_fused_matches_tree(self):
        params = {"a": jax.random.normal(KEY, (300,)), "b": jax.random.normal(KEY, (64, 8))}
        grads = jax.tree.map(lambda p: p * 0.1 + 0.01, params)
        s1 = adamw_init(params)
        s2 = adamw_init(params)
        cfg_t = AdamWConfig(lr=1e-3, weight_decay=0.1, apply_fused=False)
        cfg_f = AdamWConfig(lr=1e-3, weight_decay=0.1, apply_fused=True)
        p1, s1, _ = adamw_update(params, grads, s1, cfg_t)
        p2, s2, _ = adamw_update(params, grads, s2, cfg_f)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

    def test_clip_scales_update(self):
        params = {"a": jnp.zeros((100,))}
        grads = {"a": jnp.full((100,), 10.0)}
        st = adamw_init(params)
        cfg = AdamWConfig(lr=1.0, b1=0.0, b2=0.0, eps=0.0, weight_decay=0.0, clip_norm=1.0)
        p, st, m = adamw_update(params, grads, st, cfg)
        # after clip to norm 1, each grad component = 10/100 = 0.1; adam with
        # b1=b2=0 -> update = g/|g| = sign -> p = -lr * 1
        assert float(m["grad_norm"]) == pytest.approx(100.0)
        np.testing.assert_allclose(np.asarray(p["a"]), -1.0, rtol=1e-5)

    def test_pipelined_clip_uses_previous_norm(self):
        """Step 1 clips by prev_norm=1 (no-op for small grads); the norm
        computed at step 1 is what step 2's clip consumes."""
        params = {"a": jnp.zeros((4,))}
        st = adamw_init(params)
        cfg = AdamWConfig(lr=0.0, clip_norm=1.0, pipelined_clip=True)
        g1 = {"a": jnp.full((4,), 100.0)}
        _, st, m1 = adamw_update(params, g1, st, cfg)
        assert float(st.prev_norm) == pytest.approx(200.0)
        _, _, m2 = adamw_update(params, g1, st, cfg)
        assert float(m2["grad_norm"]) == pytest.approx(200.0)

    def test_warmup_cosine(self):
        f = warmup_cosine(1.0, 10, 100)
        assert float(f(jnp.int32(0))) == 0.0
        assert float(f(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(f(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


class TestTrainLoop:
    @pytest.mark.parametrize("micro", [1, 2])
    def test_loss_decreases(self, micro):
        api = _tiny_api()
        tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3, clip_norm=1.0), microbatches=micro)
        step_fn = jax.jit(make_train_step(api, tc))
        state = init_train_state(api, KEY)
        dc = SyntheticConfig(batch=4, seq_len=64, vocab_size=api.cfg.vocab_size, seed=1)
        losses = []
        for s in range(80):
            batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, s).items()}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        tail = float(np.mean(losses[-5:]))
        head = float(np.mean(losses[:5]))
        assert tail < head * 0.8, (head, tail, losses[::16])
        assert int(state.step) == 80

    def test_remat_matches_no_remat(self):
        api = _tiny_api()
        state = init_train_state(api, KEY)
        dc = SyntheticConfig(batch=2, seq_len=32, vocab_size=api.cfg.vocab_size)
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, 0).items()}
        s1, m1 = jax.jit(make_train_step(api, TrainConfig(remat=False)))(state, batch)
        s2, m2 = jax.jit(make_train_step(api, TrainConfig(remat=True)))(state, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)

    def test_moe_aux_loss_flows(self):
        api = _tiny_api("olmoe-1b-7b")
        state = init_train_state(api, KEY)
        dc = SyntheticConfig(batch=2, seq_len=32, vocab_size=api.cfg.vocab_size)
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, 0).items()}
        _, metrics = jax.jit(make_train_step(api, TrainConfig()))(state, batch)
        assert float(metrics["aux"]) > 0.0


class TestData:
    def test_deterministic(self):
        dc = SyntheticConfig(batch=4, seq_len=16, vocab_size=100, seed=3)
        a = batch_for_step(dc, 7)
        b = batch_for_step(dc, 7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = batch_for_step(dc, 8)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_prefetch_order(self):
        dc = SyntheticConfig(batch=2, seq_len=8, vocab_size=50, seed=4)
        got = list(prefetch_batches(dc, 5, 4))
        assert len(got) == 4
        np.testing.assert_array_equal(got[0]["tokens"], batch_for_step(dc, 5)["tokens"])
        np.testing.assert_array_equal(got[3]["tokens"], batch_for_step(dc, 8)["tokens"])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        api = _tiny_api()
        state = init_train_state(api, KEY)
        save_checkpoint(str(tmp_path), 5, state)
        assert latest_step(str(tmp_path)) == 5
        template = jax.eval_shape(lambda: state)
        restored = restore_checkpoint(str(tmp_path), 5, template)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shape_mismatch_rejected(self, tmp_path):
        state = {"w": jnp.zeros((4, 4))}
        save_checkpoint(str(tmp_path), 1, state)
        bad_template = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
        with pytest.raises(ValueError, match="shape mismatch"):
            restore_checkpoint(str(tmp_path), 1, bad_template)

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), save_every=1, keep=2, async_save=False)
        state = {"w": jnp.zeros((2,))}
        for s in range(1, 6):
            mgr.maybe_save(s, state)
        assert available_steps(str(tmp_path)) == [4, 5]

    def test_restore_latest_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        st, s = mgr.restore_latest({"w": jax.ShapeDtypeStruct((2,), jnp.float32)})
        assert st is None and s is None


class TestFaultRecovery:
    def test_recovery_replays_exactly(self, tmp_path):
        """Inject a crash mid-run; the supervised loop must resume from the
        checkpoint and end bit-identical to the crash-free run."""
        api = _tiny_api()
        tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
        step_jit = jax.jit(make_train_step(api, tc))
        dc = SyntheticConfig(batch=2, seq_len=32, vocab_size=api.cfg.vocab_size, seed=9)

        def step_fn_factory(crash_at=None):
            fired = {"done": False}

            def fn(state, step):
                if crash_at is not None and step == crash_at and not fired["done"]:
                    fired["done"] = True
                    raise RuntimeError("injected node failure")
                batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, step).items()}
                new_state, _ = step_jit(state, batch)
                return new_state

            return fn

        init = init_train_state(api, KEY)
        # crash-free reference
        ref = init
        for s in range(8):
            ref = step_fn_factory()(ref, s)

        mgr = CheckpointManager(str(tmp_path), save_every=2, keep=5, async_save=False)
        final, end = run_with_recovery(
            step_fn_factory(crash_at=5), init, 8, mgr, max_restarts=2
        )
        assert end == 8
        for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(final.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestServe:
    def test_generate_greedy_deterministic(self):
        api = _tiny_api()
        params = api.init_params(KEY)
        toks = jax.random.randint(KEY, (2, 8), 0, api.cfg.vocab_size)
        out1 = generate(api, params, {"tokens": toks}, ServeConfig(max_new_tokens=6))
        out2 = generate(api, params, {"tokens": toks}, ServeConfig(max_new_tokens=6))
        assert out1.shape == (2, 14)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert bool((out1 >= 0).all()) and bool((out1 < api.cfg.vocab_size).all())

    def test_generate_matches_stepwise_decode(self):
        """Engine output must equal manual prefill + argmax decode."""
        api = _tiny_api()
        params = api.init_params(KEY)
        toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, api.cfg.vocab_size)
        out = generate(api, params, {"tokens": toks}, ServeConfig(max_new_tokens=3))

        logits, _ = api.prefill(params, {"tokens": toks})
        cache = api.init_cache(1, 11)
        # replay prefix through decode to fill the cache
        for t in range(8):
            lg, cache = api.decode(params, toks[:, t : t + 1], cache, jnp.int32(t))
        cur = jnp.argmax(lg[:, 0], -1).astype(jnp.int32)
        manual = [cur]
        for i in range(2):
            lg, cache = api.decode(params, cur[:, None], cache, jnp.int32(8 + i))
            cur = jnp.argmax(lg[:, 0], -1).astype(jnp.int32)
            manual.append(cur)
        np.testing.assert_array_equal(np.asarray(out[0, 8:]), np.asarray(jnp.stack(manual, 1)[0]))
