"""The observability subsystem (``repro.obs``) and its zero-overhead claim.

What these tests pin down:

* ``convergence_curve`` — the one NaN-trim implementation, including the
  exactly-maxiter history (no NaN tail: the whole row IS the curve) and
  the batched ragged form; ``iterations_from_history`` per-rhs counts;
* **zero overhead while disabled** — every metric value is exactly zero
  after a full plan+solve cycle, no spans are recorded, and the solve
  loop's jaxpr is *byte-identical* with observability on vs off (the
  instrumentation uses ``jax.named_scope``, which adds no primitives —
  asserted both by string equality and by the while-body census);
* ``SolveReport`` — curve/launches/bandwidth/cache fields on warm solves,
  cold-start refusal to derive per-iteration numbers, distributed plans;
* plan-cache and trace-count telemetry under repeated and cross-key
  solves;
* serve-tier per-rhs iteration derivation + batch occupancy metrics;
* ``tools/bench_gate.py`` — pass on self-compare, fail on structural /
  timing / missing-key regressions, env-gating of timing comparisons.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import obs
from repro.kernels.common import count_primitive, while_body_jaxpr
from repro.plan import clear_plan_cache, plan_cache_stats
from repro.sparse import poisson27, spmv

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends disabled with empty spans/metrics."""
    obs.disable()
    obs.clear_spans()
    obs.reset_metrics()
    yield
    obs.disable()
    obs.clear_spans()
    obs.reset_metrics()


def _system(grid=8):
    A = poisson27(grid)
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
    return A, xstar, spmv(A, xstar)


# ---------------------------------------------------------------------------
# convergence_curve / iterations_from_history
# ---------------------------------------------------------------------------

class TestConvergenceCurve:
    def test_trims_nan_tail(self):
        h = np.array([1.0, 0.5, 0.1, np.nan, np.nan])
        c = obs.convergence_curve(h)
        np.testing.assert_array_equal(c, [1.0, 0.5, 0.1])

    def test_exactly_maxiter_no_nan_tail(self):
        # all maxiter+1 entries real: slicing at "first NaN" would drop
        # the final residual — the whole row is the curve
        h = np.array([1.0, 0.5, 0.25, 0.1])
        c = obs.convergence_curve(h)
        assert len(c) == 4 and c[-1] == 0.1

    def test_batched_ragged(self):
        h = np.array([
            [1.0, 0.5, 0.1, np.nan],
            [1.0, np.nan, np.nan, np.nan],
            [1.0, 0.9, 0.8, 0.7],          # ran to maxiter
        ])
        curves = obs.convergence_curve(h)
        assert [len(c) for c in curves] == [3, 1, 4]

    def test_accepts_solve_result(self):
        A, xstar, b = _system()
        res = repro.solve(A, b, method="pipecg", M="jacobi", atol=1e-5, maxiter=200)
        c = obs.convergence_curve(res)
        assert len(c) == int(res.iterations) + 1
        assert c[-1] < c[0]  # it converged: the curve went down

    def test_iterations_from_history(self):
        h = np.array([
            [1.0, 0.5, 0.1, np.nan],
            [1.0, np.nan, np.nan, np.nan],
            [1.0, 0.9, 0.8, 0.7],
        ])
        np.testing.assert_array_equal(obs.iterations_from_history(h), [2, 0, 3])
        assert obs.iterations_from_history(h[0]) == 2
        assert isinstance(obs.iterations_from_history(h[0]), int)

    def test_3d_history_rejected(self):
        with pytest.raises(ValueError):
            obs.convergence_curve(np.zeros((2, 2, 2)))


# ---------------------------------------------------------------------------
# zero overhead while disabled
# ---------------------------------------------------------------------------

class TestDisabledIsFree:
    def test_metrics_exactly_zero_after_solves(self):
        A, xstar, b = _system()
        p = repro.plan(A, method="pipecg", M="jacobi", atol=1e-5, maxiter=200)
        p.solve(b)
        p.solve(2.0 * b)
        p.solve_batched(jnp.stack([b, -b]))
        for name, d in obs.snapshot().items():
            if d["kind"] == "histogram":
                assert d["count"] == 0, name
            else:
                assert d["value"] == 0.0, name
        assert obs.span_tree() == ()
        assert p.last_report is None

    def test_span_yields_none_when_disabled(self):
        with obs.span("x", a=1) as sp:
            assert sp is None
        assert obs.span_tree() == ()

    @pytest.mark.parametrize("engine", ["jnp", "pallas"])
    def test_jaxpr_byte_identical_on_off(self, engine):
        # THE zero-overhead proof: the traced solve program is the same
        # string with observability on or off — named_scope adds nothing
        A, xstar, b = _system(6)
        args = (b, jnp.zeros_like(b), jnp.float32(1e-5), jnp.float32(0.0))

        def jaxpr_text():
            p = repro.plan(A, method="pipecg", engine=engine, M="jacobi",
                           atol=1e-5, maxiter=50)
            return str(jax.make_jaxpr(p._inner)(*args))

        off = jaxpr_text()
        obs.enable()
        on = jaxpr_text()
        assert on == off

    def test_while_body_census_identical(self):
        # and the census view of the same fact: zero extra primitives in
        # the iteration body with observability enabled
        A, xstar, b = _system(6)
        args = (b, jnp.zeros_like(b), jnp.float32(1e-5), jnp.float32(0.0))

        def body_counts():
            p = repro.plan(A, method="pipecg", engine="pallas", M="jacobi",
                           atol=1e-5, maxiter=50)
            body = while_body_jaxpr(jax.make_jaxpr(p._inner)(*args).jaxpr)
            return {prim: count_primitive(body, prim)
                    for prim in ("pallas_call", "dot_general", "add", "mul")}

        off = body_counts()
        obs.enable()
        assert body_counts() == off


# ---------------------------------------------------------------------------
# spans + metrics while enabled
# ---------------------------------------------------------------------------

class TestEnabled:
    def test_span_tree_nesting_and_attrs(self):
        obs.enable()
        with obs.span("outer", k=1) as sp:
            assert sp is not None and sp.attrs["k"] == 1
            with obs.span("inner"):
                pass
        roots = obs.span_tree()
        assert [r.name for r in roots] == ["outer"]
        assert roots[0].find("inner") is not None
        assert roots[0].duration_s >= roots[0].children[0].duration_s

    def test_plan_build_span_structure(self):
        obs.enable()
        A, xstar, b = _system(6)
        repro.plan(A, method="pipecg", M="jacobi", atol=1e-5, maxiter=50)
        build = next(s for s in obs.span_tree() if s.name == "plan.build")
        assert build.find("plan.resolve_pc") is not None
        assert build.find("plan.pin_core") is not None
        assert obs.snapshot()["plan.builds"]["value"] == 1.0

    def test_metric_kind_clash_raises(self):
        obs.counter("x.same")
        with pytest.raises(TypeError):
            obs.gauge("x.same")

    def test_histogram_stats(self):
        obs.enable()
        h = obs.histogram("h")
        for v in (1.0, 2.0, 3.0, 4.0):
            h.record(v)
        d = h.to_dict()
        assert d["count"] == 4 and d["min"] == 1.0 and d["max"] == 4.0
        assert d["mean"] == 2.5

    def test_dump_sinks(self, tmp_path):
        obs.enable()
        obs.counter("c").inc(3)
        with obs.span("s"):
            pass
        mpath, spath = tmp_path / "m.jsonl", tmp_path / "s.json"
        obs.dump_jsonl(str(mpath))
        obs.dump_spans(str(spath))
        lines = [json.loads(l) for l in mpath.read_text().splitlines()]
        assert any(d["name"] == "c" and d["value"] == 3 for d in lines)
        assert json.loads(spath.read_text())["spans"][0]["name"] == "s"


# ---------------------------------------------------------------------------
# SolveReport
# ---------------------------------------------------------------------------

class TestSolveReport:
    def test_warm_solve_report_fields(self):
        obs.enable()
        A, xstar, b = _system()
        p = repro.plan(A, method="pipecg", engine="pallas", M="jacobi",
                       atol=1e-5, maxiter=200)
        r1 = p.solve(b)
        cold = p.last_report
        assert cold is not None and cold.cold_start
        # cold report keeps honest wall time but refuses derived rates
        assert cold.time_s is not None
        assert cold.time_per_iter_s is None and cold.achieved_gbs is None

        p.solve(2.0 * b)
        rep = p.last_report
        assert not rep.cold_start
        assert rep.iterations > 0 and rep.converged
        assert len(rep.curve) == rep.iterations + 1
        # on CPU the SPMV engine resolves to jnp, so the fused VMA kernel
        # is the one pallas_call in the loop body
        assert rep.launches_per_iter == 1
        assert rep.achieved_gbs is not None and rep.achieved_gbs > 0
        assert 0 < rep.frac_of_hbm_peak < 1
        assert rep.env["backend"] == jax.default_backend()
        assert rep.trace_count == p.trace_count
        s = rep.summary()
        assert "launches" in s and "bandwidth" in s
        d = json.loads(rep.to_json())
        assert d["iterations"] == rep.iterations
        assert len(d["curve"]) == rep.iterations + 1

    def test_rr_events(self):
        obs.enable()
        A, xstar, b = _system()
        p = repro.plan(A, method="pipecg", M="jacobi", atol=1e-12, rtol=0.0,
                       maxiter=40, replace_every=10)
        p.solve(b)
        rep = p.last_report
        assert rep.replace_every == 10
        assert rep.rr_events == rep.iterations // 10

    def test_batched_report_uses_worst_lane(self):
        obs.enable()
        A, xstar, b = _system()
        p = repro.plan(A, method="pipecg", M="jacobi", atol=1e-5, maxiter=200)
        res = p.solve_batched(jnp.stack([b, 1e-8 * b]))
        rep = p.last_report
        iters = obs.iterations_from_history(res.history)
        assert rep.iterations == int(iters.max())
        assert len(rep.curve) == int(iters.max()) + 1

    def test_structural_bytes_model(self):
        assert obs.structural_bytes_per_elem("fused_iter", 27) == (27 + 19) * 4
        assert obs.structural_bytes_per_elem("jnp", 27) == (29 + 24 + 3 + 6) * 4
        assert obs.structural_bytes_per_elem("not-a-core", 27) is None

    def test_comparable_env(self):
        e = obs.env_fingerprint()
        assert obs.comparable_env(e, dict(e))
        other = dict(e, device_kind="TPU v4")
        assert not obs.comparable_env(e, other)


# ---------------------------------------------------------------------------
# plan cache + trace count telemetry
# ---------------------------------------------------------------------------

class TestPlanTelemetry:
    def test_repeated_and_cross_key_solves(self):
        obs.enable()
        clear_plan_cache()
        A, xstar, b = _system(6)
        for _ in range(3):
            repro.solve(A, b, method="pipecg", M="jacobi", atol=1e-5, maxiter=100)
        stats = plan_cache_stats()
        assert stats["misses"] == 1 and stats["hits"] == 2
        # a different key (method) is a fresh plan, not a hit
        repro.solve(A, b, method="pcg", M="jacobi", atol=1e-5, maxiter=100)
        stats = plan_cache_stats()
        assert stats["misses"] == 2 and stats["hits"] == 2
        snap = obs.snapshot()
        assert snap["plan_cache.hits"]["value"] == 2.0
        assert snap["plan_cache.misses"]["value"] == 2.0
        assert snap["plan_cache.size"]["value"] == stats["size"]

    def test_trace_count_stays_one_across_solves(self):
        obs.enable()
        A, xstar, b = _system(6)
        p = repro.plan(A, method="pipecg", M="jacobi", atol=1e-5, maxiter=100)
        for i in range(4):
            p.solve(b + float(i))
        assert p.trace_count == 1  # same shapes: the pinned program is reused
        snap = obs.snapshot()
        assert snap["plan.solves"]["value"] == 4.0
        assert snap["plan.cold_solves"]["value"] == 1.0
        assert snap["plan.solve_time_s"]["count"] == 3  # warm solves only


# ---------------------------------------------------------------------------
# serve tier: per-rhs iterations + occupancy metrics
# ---------------------------------------------------------------------------

class TestServeTelemetry:
    def test_per_rhs_iterations_from_history(self):
        from repro.serve.engine import SolverEngine

        A, xstar, b = _system()
        eng = SolverEngine(A, M="jacobi", method="pipecg", atol=1e-5, maxiter=200)
        easy, easier, zero = b, 1e-6 * b, jnp.zeros_like(b)
        out = eng.solve_batch(jnp.stack([easy, easier, zero]))
        iters = np.asarray(out.iterations)
        assert iters.shape == (3,)
        # per-rhs counts, not the shared worst-case stop
        single = [int(eng.solve(v).iterations) for v in (easy, easier, zero)]
        np.testing.assert_array_equal(iters, single)
        assert iters[2] == 0  # zero rhs: converged at iteration 0

    def test_occupancy_metrics(self):
        from repro.serve.engine import SolverEngine

        obs.enable()
        A, xstar, b = _system(6)
        eng = SolverEngine(A, M="jacobi", method="pipecg", atol=1e-5,
                           maxiter=100, max_batch=2)
        eng.solve_batch(jnp.stack([b, 2.0 * b, -b]))  # 2 buckets, 1 padded lane
        snap = obs.snapshot()
        assert snap["serve.requests"]["value"] == 3.0
        assert snap["serve.buckets"]["value"] == 2.0
        assert snap["serve.padded_lanes"]["value"] == 1.0
        occ = snap["serve.batch_occupancy"]
        assert occ["count"] == 2 and occ["min"] == 0.5 and occ["max"] == 1.0
        assert snap["serve.rhs_iterations"]["count"] == 3
        assert "serve.wasted_lane_iterations" in snap


# ---------------------------------------------------------------------------
# bench_gate
# ---------------------------------------------------------------------------

def _run_gate(baseline, current, *extra):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "bench_gate.py"),
         "--baseline", str(baseline), "--current", str(current), *extra],
        capture_output=True, text=True,
    )


class TestBenchGate:
    BASE = {
        "bench": "kernels", "schema": 2,
        "env": {"backend": "cpu", "device_kind": "cpu", "x64": False},
        "cores": {
            "fused_iter": {"us_per_iter": 100.0, "launches_per_iter": 1,
                           "bytes_per_elem": 184.0, "achieved_gbs": 2.0},
        },
        "iters_pcg": 10,
    }

    def _write(self, d, rec):
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "BENCH_kernels.json"), "w") as f:
            json.dump(rec, f)

    def test_self_compare_passes(self, tmp_path):
        self._write(tmp_path / "a", self.BASE)
        self._write(tmp_path / "b", self.BASE)
        p = _run_gate(tmp_path / "a", tmp_path / "b")
        assert p.returncode == 0, p.stdout + p.stderr

    def test_structural_regression_fails(self, tmp_path):
        cur = json.loads(json.dumps(self.BASE))
        cur["cores"]["fused_iter"]["launches_per_iter"] = 2
        self._write(tmp_path / "a", self.BASE)
        self._write(tmp_path / "b", cur)
        p = _run_gate(tmp_path / "a", tmp_path / "b")
        assert p.returncode == 1
        assert "structural regression" in p.stderr

    def test_timing_band(self, tmp_path):
        cur = json.loads(json.dumps(self.BASE))
        cur["cores"]["fused_iter"]["us_per_iter"] = 200.0  # 2x: inside 2.5x band
        self._write(tmp_path / "a", self.BASE)
        self._write(tmp_path / "b", cur)
        assert _run_gate(tmp_path / "a", tmp_path / "b",
                         "--time-band", "2.5").returncode == 0
        cur["cores"]["fused_iter"]["us_per_iter"] = 300.0  # 3x: outside
        self._write(tmp_path / "b", cur)
        p = _run_gate(tmp_path / "a", tmp_path / "b", "--time-band", "2.5")
        assert p.returncode == 1 and "timing regression" in p.stderr

    def test_timing_skipped_when_env_differs(self, tmp_path):
        cur = json.loads(json.dumps(self.BASE))
        cur["cores"]["fused_iter"]["us_per_iter"] = 1e6
        cur["env"]["device_kind"] = "TPU v4"
        self._write(tmp_path / "a", self.BASE)
        self._write(tmp_path / "b", cur)
        p = _run_gate(tmp_path / "a", tmp_path / "b")
        assert p.returncode == 0
        assert "env fingerprints differ" in p.stdout

    def test_missing_key_fails(self, tmp_path):
        cur = json.loads(json.dumps(self.BASE))
        del cur["cores"]["fused_iter"]["us_per_iter"]
        self._write(tmp_path / "a", self.BASE)
        self._write(tmp_path / "b", cur)
        p = _run_gate(tmp_path / "a", tmp_path / "b")
        assert p.returncode == 1 and "MISSING in current" in p.stderr

    def test_convergence_band(self, tmp_path):
        cur = json.loads(json.dumps(self.BASE))
        cur["iters_pcg"] = 12  # +20% > 10% band
        self._write(tmp_path / "a", self.BASE)
        self._write(tmp_path / "b", cur)
        p = _run_gate(tmp_path / "a", tmp_path / "b")
        assert p.returncode == 1 and "convergence regression" in p.stderr

    def test_update_refreshes_baseline(self, tmp_path):
        cur = json.loads(json.dumps(self.BASE))
        cur["cores"]["fused_iter"]["launches_per_iter"] = 5
        self._write(tmp_path / "b", cur)
        p = _run_gate(tmp_path / "a", tmp_path / "b", "--update")
        assert p.returncode == 0
        with open(tmp_path / "a" / "BENCH_kernels.json") as f:
            assert json.load(f)["cores"]["fused_iter"]["launches_per_iter"] == 5

    def test_committed_trajectory_gates_itself(self):
        traj = os.path.join(REPO, "benchmarks", "trajectory")
        p = _run_gate(traj, traj)
        assert p.returncode == 0, p.stdout + p.stderr
