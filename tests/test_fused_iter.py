"""The whole-iteration fused PIPECG kernel + mixed-precision SPMV engine.

Three layers, all on CPU interpret mode:

* kernel parity — ``fused_iter_step`` (one Pallas launch) vs
  ``fused_iter_ref`` (= spmv_dia_ref + the canonical ``pipecg_vma_core``
  recurrence), including cross-tile halos and the padded-tail invariant;
* solver integration — ``engine="fused_iter"`` matches ``engine="jnp"``
  iterates on non-multiple-of-tile sizes for Jacobi and identity PCs,
  launches exactly ONE kernel per iteration (jaxpr census) with zero
  per-iteration padding, and plans pin the core (trace_count stays 1);
* bf16 SPMV engine — tolerance-banded vs f32, "auto"/"segsum" engine
  resolution, and convergence with the residual-replacement safety net
  plans default on for it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core.iteration import make_fused_iter_core, resolve_core_name
from repro.core.pipecg import pipecg
from repro.core.preconditioners import jacobi
from repro.kernels import fused_iter_ref, fused_iter_step, fused_iter_tile
from repro.kernels.common import (
    ceil_to,
    count_primitive,
    launches_per_iteration,
    pad1d,
    while_body_jaxpr,
)
from repro.sparse import csr_from_dia, poisson27, resolve_engine, spmv_dia, spmv_dia_bf16, synthetic_spd_dia

TILE = 256  # small tile -> multiple grid steps (halo paths) in interpret mode


def _rand(n, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,), dtype=dtype)


def _padded_operands(A, tile, dtype=jnp.float32, seed=0):
    t = fused_iter_tile(A.bandwidth, tile)
    n_pad = ceil_to(A.n, t)
    data = jnp.pad(A.data, ((0, 0), (0, n_pad - A.n))).astype(dtype)
    vecs = [pad1d(_rand(A.n, seed + i, dtype), n_pad) for i in range(9)]
    inv = pad1d(1.0 / jnp.asarray(A.diagonal(), dtype), n_pad)
    return t, n_pad, data, vecs, inv


class TestKernelParity:
    @pytest.mark.parametrize("gen", [lambda: poisson27(7), lambda: synthetic_spd_dia(500, 9.0, seed=4)])
    def test_matches_ref(self, gen):
        A = gen()
        t, n_pad, data, vecs, inv = _padded_operands(A, TILE)
        assert n_pad > t  # multiple tiles: the halo BlockSpecs are exercised
        a, b = jnp.float32(0.3), jnp.float32(0.7)
        outs = fused_iter_step(data, A.offsets, *vecs, inv, a, b, tile=t)
        refs = fused_iter_ref(data, A.offsets, *vecs, inv, a, b)
        for got, want in zip(outs[:9], refs[:9]):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(outs[9]), np.asarray(refs[9]), rtol=1e-4, atol=1e-3)

    def test_padded_tail_stays_zero(self):
        A = poisson27(7)  # n=343: real padding
        t, n_pad, data, vecs, inv = _padded_operands(A, TILE)
        assert n_pad > A.n
        outs = fused_iter_step(data, A.offsets, *vecs, inv, jnp.float32(0.5), jnp.float32(0.25), tile=t)
        for o in outs[:9]:
            np.testing.assert_array_equal(np.asarray(o[A.n :]), 0.0)

    def test_rejects_unpadded(self):
        A = poisson27(7)
        vecs = [_rand(A.n, i) for i in range(9)]
        inv = jnp.ones((A.n,))
        with pytest.raises(ValueError, match="pre-padded"):
            fused_iter_step(A.data, A.offsets, *vecs, inv, 0.3, 0.7,
                            tile=fused_iter_tile(A.bandwidth, TILE))


class TestSolverIntegration:
    def test_jacobi_parity_with_jnp_core(self):
        A = poisson27(7)  # 343: non-multiple of every tile
        b = jnp.sin(jnp.arange(A.n, dtype=jnp.float32))
        M = jacobi(A)
        rj = pipecg(A, b, M=M, atol=1e-6, maxiter=200, engine="jnp")
        rf = pipecg(A, b, M=M, atol=1e-6, maxiter=200, engine="fused_iter")
        assert bool(rf.converged)
        assert int(rf.iterations) == int(rj.iterations)
        np.testing.assert_allclose(np.asarray(rf.x), np.asarray(rj.x), rtol=1e-4, atol=1e-5)

    def test_identity_pc_parity_with_jnp_core(self):
        A = poisson27(6)
        b = jnp.cos(jnp.arange(A.n, dtype=jnp.float32))
        # fixed 20 iterations (atol=rtol=0): compare iterates before f32
        # recurrence noise accumulates in the unpreconditioned run
        rj = pipecg(A, b, M=None, atol=0.0, rtol=0.0, maxiter=20, engine="jnp")
        rf = pipecg(A, b, M=None, atol=0.0, rtol=0.0, maxiter=20, engine="fused_iter")
        np.testing.assert_allclose(np.asarray(rf.x), np.asarray(rj.x), rtol=1e-3, atol=1e-4)

    def test_single_kernel_launch_per_iteration(self):
        A = poisson27(5)
        b = jnp.ones((A.n,), jnp.float32)
        M = jacobi(A)

        def run(engine, **kw):
            def f(bb):
                return pipecg(A, bb, M=M, atol=0.0, rtol=0.0, maxiter=10, engine=engine, **kw).x
            return f

        # the acceptance criterion: ONE pallas_call inside the while body
        assert launches_per_iteration(run("fused_iter"), b) == 1
        # contrast: the two-kernel path (VMA core + Pallas SPMV)
        assert launches_per_iteration(run("pallas", spmv_engine="pallas"), b) == 2
        # and the jnp core stages no kernels at all
        assert launches_per_iteration(run("jnp"), b) == 0

    def test_no_padding_in_hot_loop(self):
        A = poisson27(7)
        b = jnp.ones((A.n,), jnp.float32)
        M = jacobi(A)
        for engine in ("fused_iter", "pallas"):
            def f(bb, engine=engine):
                return pipecg(A, bb, M=M, atol=0.0, rtol=0.0, maxiter=10, engine=engine).x

            body = while_body_jaxpr(jax.make_jaxpr(f)(b).jaxpr)
            assert body is not None
            # on-chip kernel-internal pads are free; HBM-level pads are not
            assert count_primitive(body, "pad", into_kernels=False) == 0

    def test_requires_dia_and_elementwise_pc(self):
        A = poisson27(5)
        b = jnp.ones((A.n,), jnp.float32)
        with pytest.raises(TypeError, match="DIAMatrix"):
            pipecg(csr_from_dia(A), b, engine="fused_iter")
        from repro.core.preconditioners import block_jacobi

        with pytest.raises(ValueError, match="elementwise"):
            pipecg(A, b, M=block_jacobi(A, block=5), engine="fused_iter")

    def test_auto_resolution_on_cpu(self):
        # "auto" never picks a Pallas core off-TPU; explicit names pass through
        A = poisson27(4)
        assert resolve_core_name("auto", A) == "jnp"
        assert resolve_core_name("fused_iter", A) == "fused_iter"

    def test_core_factory_pins_padded_views(self):
        A = poisson27(7)
        core = make_fused_iter_core(A)
        assert core.fuses_spmv
        assert core.n_pad % core.tile == 0
        assert core.padded_data.shape == (A.data.shape[0], core.n_pad)

    def test_plan_pins_core_and_traces_once(self):
        A = poisson27(6)
        b = jnp.sin(jnp.arange(A.n, dtype=jnp.float32))
        p = repro.plan(A, method="pipecg", engine="fused_iter", M="jacobi",
                       atol=1e-6, maxiter=100)
        assert p._core is not None and p._core.fuses_spmv
        r1 = p.solve(b)
        r2 = p.solve(2.0 * b)
        assert p.trace_count == 1  # pinned program reused across rhs
        assert bool(r1.converged) and bool(r2.converged)
        np.testing.assert_allclose(np.asarray(r2.x), 2.0 * np.asarray(r1.x), rtol=1e-4, atol=1e-4)
        d = p.describe()
        assert d["core"] == "fused_iter"


class TestBf16Engine:
    def test_tolerance_band_vs_f32(self):
        A = poisson27(7)
        x = _rand(A.n, 3)
        y32 = np.asarray(spmv_dia(A, x), np.float64)
        y16 = np.asarray(spmv_dia_bf16(A, x), np.float64)
        rel = np.linalg.norm(y32 - y16) / np.linalg.norm(y32)
        assert rel < 2e-2  # bf16 storage error band
        assert rel > 0.0  # actually reduced precision, not a f32 alias
        assert spmv_dia_bf16(A, x).dtype == x.dtype

    def test_resolve_engine(self):
        from repro.sparse import csr_device_from_host

        A = poisson27(4)
        C = csr_device_from_host(csr_from_dia(A))
        assert resolve_engine(A, "bf16") == "bf16"
        if jax.default_backend() != "tpu":
            # satellite fix: CSR "auto" prefers the segsum engine off-TPU
            assert resolve_engine(C, "auto") == "segsum"
            assert resolve_engine(A, "auto") == "jnp"
        assert resolve_engine(C, "nonesuch") == "jnp"  # fallback

    def test_converges_with_residual_replacement(self):
        A = poisson27(7)
        b = jnp.sin(jnp.arange(A.n, dtype=jnp.float32))
        p = repro.plan(A, method="pipecg", engine="jnp", M="jacobi",
                       spmv_engine="bf16", atol=0.0, rtol=1e-2, maxiter=500)
        assert p.describe()["replace_every"] > 0  # safety net defaults ON
        r = p.solve(b)
        assert bool(r.converged)
        # true residual lands in the bf16 band, not just the recurrence one
        true_rel = float(jnp.linalg.norm(b - spmv_dia(A, r.x)) / jnp.linalg.norm(b))
        assert true_rel < 5e-2

    def test_explicit_replace_every_zero_respected(self):
        A = poisson27(5)
        b = jnp.ones((A.n,), jnp.float32)
        p = repro.plan(A, method="pipecg", engine="jnp", M="jacobi",
                       spmv_engine="bf16", replace_every=0, rtol=1e-2, maxiter=200)
        # the explicit 0 overrides the bf16 default — no safety net
        assert p.describe()["replace_every"] == 0
        r = p.solve(b)
        assert bool(jnp.all(jnp.isfinite(r.x)))  # runs; convergence not promised
