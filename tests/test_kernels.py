"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    flash_attention,
    flash_attention_ref,
    fused_adamw,
    fused_adamw_ref,
    fused_dots,
    fused_dots_ref,
    fused_vma_dots,
    fused_vma_dots_ref,
    spmv_bell_pallas,
    spmv_bell_ref,
    spmv_dia_pallas,
    spmv_dia_ref,
)
from repro.sparse import bell_from_csr, csr_from_dia, poisson27, poisson125, synthetic_spd_dia

SIZES = [100, 1023, 4096, 20000]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


def _rand(n, dtype, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,), dtype=dtype)


class TestFusedVMA:
    @pytest.mark.parametrize("n", SIZES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, n, dtype):
        vecs = [_rand(n, dtype, seed=i) for i in range(10)]
        inv = jnp.abs(_rand(n, dtype, seed=99)) + 0.5
        alpha, beta = 0.37, 0.81
        out_k = fused_vma_dots(*vecs, inv, alpha, beta)
        out_r = fused_vma_dots_ref(*vecs, inv, alpha, beta)
        for i, (a, b) in enumerate(zip(out_k[:9], out_r[:9])):
            np.testing.assert_allclose(
                np.asarray(a, np.float64), np.asarray(b, np.float64), **_tol(dtype)
            )
        # dots: f32 accumulation, compare relative to magnitude ~ n
        np.testing.assert_allclose(
            np.asarray(out_k[9]), np.asarray(out_r[9]), rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4
        )

    def test_beta_zero_first_iteration(self):
        n = 512
        vecs = [_rand(n, jnp.float32, seed=i) for i in range(10)]
        inv = jnp.ones((n,))
        out_k = fused_vma_dots(*vecs, inv, 0.5, 0.0)
        out_r = fused_vma_dots_ref(*vecs, inv, 0.5, 0.0)
        np.testing.assert_allclose(np.asarray(out_k[0]), np.asarray(out_r[0]), rtol=1e-6)


class TestFusedDot:
    @pytest.mark.parametrize("n", SIZES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, n, dtype):
        r, u, w = (_rand(n, dtype, seed=i) for i in range(3))
        k = np.asarray(fused_dots(r, u, w))
        ref = np.asarray(fused_dots_ref(r, u, w))
        np.testing.assert_allclose(k, ref, rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4)

    def test_uu_nonnegative(self):
        u = _rand(1000, jnp.float32, seed=5)
        k = np.asarray(fused_dots(u, u, u))
        assert k[2] >= 0


class TestSpmvDia:
    @pytest.mark.parametrize("gen,n", [(poisson27, 6), (poisson27, 9), (poisson125, 6)])
    def test_stencils(self, gen, n):
        A = gen(n)
        x = _rand(A.n, jnp.float32, seed=1)
        y_k = np.asarray(spmv_dia_pallas(A, x, tile=512))
        y_r = np.asarray(spmv_dia_ref(A.data, A.offsets, x))
        np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("n", [100, 700])
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_random_banded(self, n, dtype):
        A = synthetic_spd_dia(n, 9.0, seed=3).with_dtype(dtype)
        x = _rand(n, dtype, seed=2)
        y_k = np.asarray(spmv_dia_pallas(A, x, tile=128), np.float64)
        y_r = np.asarray(spmv_dia_ref(A.data, A.offsets, x), np.float64)
        np.testing.assert_allclose(y_k, y_r, **_tol(dtype))

    def test_tile_auto_raise_for_wide_band(self):
        A = poisson125(8)  # bandwidth 2*64+16+2 = 146... with n=8: 2*64+2*8+2
        x = _rand(A.n, jnp.float32, seed=4)
        # tile smaller than bandwidth must be raised internally, not crash
        y_k = np.asarray(spmv_dia_pallas(A, x, tile=128))
        y_r = np.asarray(spmv_dia_ref(A.data, A.offsets, x))
        np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)


class TestSpmvBell:
    @pytest.mark.parametrize("n", [64, 300, 2048])
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, n, dtype):
        A = synthetic_spd_dia(n, 7.0, seed=5).with_dtype(dtype)
        B = bell_from_csr(csr_from_dia(A))
        x = _rand(n, dtype, seed=6)
        y_k = np.asarray(spmv_bell_pallas(B, x), np.float64)
        y_r = np.asarray(spmv_bell_ref(B.cols, B.vals, x), np.float64)
        np.testing.assert_allclose(y_k, y_r, **_tol(dtype))

    def test_vmem_guard(self):
        from repro.sparse.formats import BellMatrix

        big = BellMatrix(jnp.zeros((3 * 1024 * 1024, 1), jnp.int32), jnp.zeros((3 * 1024 * 1024, 1)), 3 * 1024 * 1024)
        with pytest.raises(ValueError, match="VMEM"):
            spmv_bell_pallas(big, jnp.zeros((3 * 1024 * 1024,)))


class TestFlashAttention:
    @pytest.mark.parametrize(
        "B,T,H,KV,hd",
        [(2, 256, 4, 2, 64), (1, 128, 8, 8, 32), (2, 384, 6, 3, 64), (1, 256, 4, 1, 16)],
    )
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, B, T, H, KV, hd, dtype):
        q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd), dtype)
        k = jax.random.normal(jax.random.PRNGKey(1), (B, T, KV, hd), dtype)
        v = jax.random.normal(jax.random.PRNGKey(2), (B, T, KV, hd), dtype)
        o = flash_attention(q, k, v, q_tile=128, kv_tile=128)
        r = flash_attention_ref(q, k, v)
        tol = 4e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(r, np.float32), rtol=tol, atol=tol
        )

    def test_noncausal(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 2, 32), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 32), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 32), jnp.float32)
        o = flash_attention(q, k, v, causal=False, q_tile=128, kv_tile=128)
        r = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-5, atol=2e-5)

    def test_tile_divisibility_guard(self):
        q = jnp.zeros((1, 100, 2, 32))
        with pytest.raises(ValueError, match="%"):
            flash_attention(q, q[:, :, :2], q[:, :, :2], q_tile=64)


class TestFusedAdam:
    @pytest.mark.parametrize("n", SIZES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, n, dtype):
        p = _rand(n, dtype, seed=1)
        g = _rand(n, dtype, seed=2)
        m = jnp.zeros((n,), jnp.float32)
        v = jnp.zeros((n,), jnp.float32)
        for step in (1.0, 10.0):
            pk, mk, vk = fused_adamw(p, g, m, v, lr=3e-4, wd=0.1, step=step)
            pr, mr, vr = fused_adamw_ref(p, g, m, v, 3e-4, 0.9, 0.999, 1e-8, 0.1, step)
            np.testing.assert_allclose(np.asarray(pk, np.float64), np.asarray(pr, np.float64), **_tol(dtype))
            np.testing.assert_allclose(np.asarray(mk), np.asarray(mr), rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), rtol=1e-5, atol=1e-6)
            p, m, v = pk, mk, vk

    def test_wd_zero_equals_adam(self):
        n = 500
        p = _rand(n, jnp.float32, seed=3)
        g = _rand(n, jnp.float32, seed=4)
        m = v = jnp.zeros((n,), jnp.float32)
        p1, _, _ = fused_adamw(p, g, m, v, lr=1e-3, wd=0.0)
        # hand-rolled adam step 1
        mh = 0.1 * np.asarray(g) / (1 - 0.9)
        vh = 0.001 * np.asarray(g) ** 2 / (1 - 0.999)
        expect = np.asarray(p) - 1e-3 * (mh / (np.sqrt(vh) + 1e-8))
        np.testing.assert_allclose(np.asarray(p1), expect, rtol=1e-5, atol=1e-6)
