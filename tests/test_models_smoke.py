"""Per-architecture smoke tests (reduced configs, CPU): one forward +
one train-style grad step; shape and finiteness assertions; prefill->decode
consistency for the cache/state machinery.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs, reduced
from repro.models import build_model

ARCHS = list_configs()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, api, B=2, T=32, key=KEY):
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), api.dtype)
    if cfg.family == "vlm":
        batch["img_feats"] = jax.random.normal(key, (B, cfg.n_img_tokens, cfg.d_model), api.dtype)
    return batch


def _logits(api, params, batch):
    out = api.forward(params, batch)
    return out[0] if isinstance(out, tuple) else out


class TestAllArchsRegistered:
    def test_ten_archs(self):
        assert len(ARCHS) == 10, ARCHS

    def test_exact_published_dims(self):
        spot = {
            "qwen2.5-14b": dict(n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824, vocab_size=152064),
            "olmoe-1b-7b": dict(n_layers=16, d_model=2048, n_experts=64, top_k=8),
            "zamba2-2.7b": dict(n_layers=54, d_model=2560, ssm_state=64, vocab_size=32000),
            "xlstm-1.3b": dict(n_layers=48, d_model=2048, n_heads=4),
            "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6, d_ff=1536, vocab_size=51865),
            "llama-3.2-vision-11b": dict(n_layers=40, d_model=4096, d_ff=14336, vocab_size=128256),
            "granite-moe-1b-a400m": dict(d_ff=512, n_experts=32, top_k=8, vocab_size=49155),
            "stablelm-1.6b": dict(d_ff=5632, vocab_size=100352),
            "internlm2-1.8b": dict(d_ff=8192, vocab_size=92544),
            "qwen3-8b": dict(n_layers=36, d_ff=12288, vocab_size=151936),
        }
        for name, want in spot.items():
            cfg = get_config(name)
            for k, v in want.items():
                assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)

    def test_shapes_assigned(self):
        assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
        assert SHAPES["train_4k"].global_batch == 256
        assert SHAPES["long_500k"].seq_len == 524288


@pytest.mark.parametrize("name", ARCHS)
class TestSmokeForward:
    def test_forward_shapes_no_nan(self, name):
        cfg = reduced(get_config(name))
        api = build_model(cfg)
        params = api.init_params(KEY)
        batch = _batch(cfg, api)
        logits = _logits(api, params, batch)
        assert logits.shape == (2, 32, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def test_train_grad_step(self, name):
        """One CE-loss grad step: finite loss, finite grads, params move."""
        cfg = reduced(get_config(name))
        api = build_model(cfg)
        params = api.init_params(KEY)
        batch = _batch(cfg, api, T=16)
        labels = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)

        def loss_fn(p):
            out = api.forward(p, batch)
            logits = out[0] if isinstance(out, tuple) else out
            lp = jax.nn.log_softmax(logits.astype(jnp.float32))
            nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
            if isinstance(out, tuple):
                nll = nll + 0.01 * out[1]
            return nll

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert bool(jnp.isfinite(loss)), name
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, name
        newp = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
        moved = any(
            bool(jnp.any(a != b)) for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(newp))
        )
        assert moved


class TestPerfKnobs:
    """Beyond-paper performance options must be math-preserving."""

    def test_chunked_attention_matches_full(self):
        from dataclasses import replace

        cfg = reduced(get_config("qwen3-8b"))
        api_full = build_model(cfg)
        api_chunk = build_model(replace(cfg, attn_chunk=8))
        params = api_full.init_params(KEY)
        batch = _batch(cfg, api_full, B=2, T=32)
        l1 = np.asarray(_logits(api_full, params, batch), np.float32)
        l2 = np.asarray(_logits(api_chunk, params, batch), np.float32)
        np.testing.assert_allclose(l1, l2, rtol=2e-2, atol=2e-3)

    def test_save_collectives_remat_matches(self):
        import jax.numpy as jnp

        from repro.data import SyntheticConfig, batch_for_step
        from repro.train import TrainConfig, init_train_state, make_train_step

        cfg = reduced(get_config("stablelm-1.6b"))
        api = build_model(cfg)
        state = init_train_state(api, KEY)
        b = {k: jnp.asarray(v) for k, v in batch_for_step(
            SyntheticConfig(2, 32, cfg.vocab_size), 0).items()}
        _, m1 = jax.jit(make_train_step(api, TrainConfig(remat=True)))(state, b)
        _, m2 = jax.jit(make_train_step(api, TrainConfig(remat="save_collectives")))(state, b)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-5)


@pytest.mark.parametrize("name", ARCHS)
class TestPrefillDecodeConsistency:
    def test_decode_matches_forward(self, name):
        """Teacher-forced forward logits at position t must match decode-
        step logits given the prefix — validates cache/state plumbing.

        MoE runs with no-drop capacity here: capacity dropping is a batch-
        level approximation that legitimately differs between batched
        routing (prefill) and per-token routing (decode)."""
        from dataclasses import replace

        cfg = reduced(get_config(name))
        if cfg.family == "moe":
            cfg = replace(cfg, moe_capacity_factor=float(cfg.n_experts))
        api = build_model(cfg)
        params = api.init_params(KEY)
        B, T = 2, 16
        batch = _batch(cfg, api, B=B, T=T, key=jax.random.PRNGKey(7))
        full = _logits(api, params, batch)  # (B, T, V)

        cache = api.init_cache(B, T)
        got = []
        for t in range(T):
            tok = batch["tokens"][:, t : t + 1]
            if cfg.family == "encdec":
                # encoder output must be present in the cache
                if t == 0:
                    from repro.models.encdec import encdec_encode

                    enc = encdec_encode(params, batch["frames"], cfg)
                    cache = cache._replace(enc_out=enc)
            if cfg.family == "vlm" and t == 0:
                cache = cache._replace(img_feats=batch["img_feats"])
            lg, cache = api.decode(params, tok, cache, jnp.int32(t))
            got.append(lg[:, 0])
        got = jnp.stack(got, axis=1)  # (B, T, V)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(full, np.float32), rtol=2e-2, atol=2e-2
        )
