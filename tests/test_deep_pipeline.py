"""Depth-l pipelined CG, hierarchical reduction, batched rhs, multi-hop halo.

The communication-reduced distributed execution paths (ISSUE 9): the
cross-method iterate-equivalence matrix, the jaxpr collective census
proving the reduction schedule of each method x reducer pair, the
multi-hop halo regression (bandwidth > shard rows), and the
single-program guarantee for distributed ``plan.solve_batched``.

Multi-device cases run in subprocesses with XLA_FLAGS set before jax
import (the main process keeps the real single-device view).
"""
import numpy as np
import pytest

from conftest import run_multidevice

import jax.numpy as jnp

from repro.core import jacobi, pipecg
from repro.core.iteration import make_deep_pipecg_core
from repro.core.reduce import make_reducer, reducer_needs_subaxis, reducer_names
from repro.sparse import balanced_rows, shard_dia, spmv, synthetic_spd_dia


# ---------------------------------------------------------------------------
# single-device pieces (no mesh needed)
# ---------------------------------------------------------------------------

class TestDeepCoreLocal:
    """The depth-l loop itself, on one device with the local reducer."""

    @pytest.mark.parametrize("l", [1, 2, 3])
    def test_matches_pcg_iterations(self, l):
        import jax

        from repro.core import pcg

        A = synthetic_spd_dia(1000, 9.0, seed=3, bandwidth=16)
        M = jacobi(A)
        rng = np.random.default_rng(0)
        b = jnp.asarray(rng.standard_normal(A.n), dtype=jnp.float32)
        # pcg is the exact-arithmetic twin: CG on the Jacobi-split system
        # (what the deep core runs) IS preconditioned CG on A
        ref = pcg(A, b, M=M, atol=1e-6, maxiter=200)

        loop = make_deep_pipecg_core(l)
        assert loop.pipeline_depth == l
        run = jax.jit(
            lambda bb: loop(
                bb, jnp.zeros_like(bb),
                spmv_fn=lambda v: spmv(A, v),
                reducer=make_reducer("local"),
                inv_diag=M.inv_diag,
                atol=1e-6, rtol=0.0, maxiter=200,
            )
        )
        iters, x, norm, conv, hist = run(b)
        assert bool(conv)
        # same Krylov space, same PC, same metric: counts agree tightly
        assert abs(int(iters) - int(ref.iterations)) <= max(1, l - 1)
        err = float(jnp.linalg.norm(b - spmv(A, x)))
        assert err < 1e-3, err

    def test_validates_depth_and_reducer(self):
        with pytest.raises(ValueError, match="depth"):
            make_deep_pipecg_core(0)
        loop = make_deep_pipecg_core(2)
        bad_reducer = lambda g, d, nn: (g, d, nn)  # no .array
        with pytest.raises(ValueError, match="array"):
            loop(
                jnp.ones(8), jnp.zeros(8), spmv_fn=lambda v: v,
                reducer=bad_reducer, atol=1e-6, rtol=0.0, maxiter=10,
            )

    def test_residual_replacement_converges(self):
        import jax

        A = synthetic_spd_dia(600, 8.0, seed=7, bandwidth=8)
        M = jacobi(A)
        b = jnp.asarray(np.random.default_rng(1).standard_normal(A.n), dtype=jnp.float32)
        loop = make_deep_pipecg_core(3)
        iters, x, norm, conv, hist = jax.jit(
            lambda bb: loop(
                bb, jnp.zeros_like(bb), spmv_fn=lambda v: spmv(A, v),
                reducer=make_reducer("local"), inv_diag=M.inv_diag,
                atol=1e-6, rtol=0.0, maxiter=300, replace_every=10,
            )
        )(b)
        assert bool(conv)
        assert float(jnp.linalg.norm(b - spmv(A, x))) < 1e-3


class TestReducerRegistry:
    def test_h4_registered_and_flagged(self):
        assert "h4" in reducer_names()
        assert reducer_needs_subaxis("h4")
        assert not reducer_needs_subaxis("packed")
        assert not reducer_needs_subaxis("local")

    def test_h4_needs_axis_tuple(self):
        with pytest.raises(ValueError, match="2-D mesh"):
            make_reducer("h4", "rows")

    def test_all_reducers_expose_array(self):
        for name in reducer_names():
            axis = ("pod", "rows") if reducer_needs_subaxis(name) else (
                None if name == "local" else "rows"
            )
            r = make_reducer(name, axis)
            assert callable(getattr(r, "array", None)), name


class TestMultiHopSharding:
    def test_equal_shards_allow_wide_band(self):
        # 8 shards of 8 rows under a bandwidth-16 stencil: legal now
        A = synthetic_spd_dia(64, 9.0, seed=5, bandwidth=16)
        As = shard_dia(A, balanced_rows(64, 8))
        assert As.rows_max == 8 and As.bandwidth > As.rows_max

    def test_unequal_shards_still_restricted(self):
        A = synthetic_spd_dia(65, 9.0, seed=5, bandwidth=16)
        bounds = balanced_rows(65, 8)  # sizes 9,9,8,... -> unequal
        with pytest.raises(ValueError, match="single-hop"):
            shard_dia(A, bounds)


# ---------------------------------------------------------------------------
# multi-device: equivalence matrix, census, multi-hop, batched single-program
# ---------------------------------------------------------------------------

_MATRIX_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import jacobi, pcg, pipecg
from repro.core.distributed import make_solver_mesh, pipecg_distributed
from repro.sparse import (balanced_rows, synthetic_spd_dia, shard_dia,
                          shard_vector, spmv, unshard_vector)
assert jax.device_count() == 8

A = synthetic_spd_dia(512, 9.0, seed=3, bandwidth=16)
M = jacobi(A)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal(A.n), dtype=jnp.float32)

# single-device anchors: pcg and pipecg agree on the solution (their
# stopping metrics differ on strongly-scaled diagonals, so iterate-count
# comparison runs against the distributed pipecg reference below)
ref_pcg = pcg(A, b, M=M, atol=1e-8, maxiter=300)
ref_pipe = pipecg(A, b, M=M, atol=1e-6, maxiter=300)
assert bool(ref_pcg.converged) and bool(ref_pipe.converged)
xstar = ref_pcg.x
assert float(jnp.linalg.norm(b - spmv(A, xstar))) < 1e-4

bounds = balanced_rows(A.n, 8)
As = shard_dia(A, bounds)
b_sh = shard_vector(b, bounds)
inv_sh = shard_vector(M.inv_diag, bounds)
mesh1 = make_solver_mesh(8)
mesh2 = make_solver_mesh(8, sub=4)

# the depth-1 distributed pipecg is the iterate-count reference all other
# method x reducer combinations must stay within the 10% band of
ref = pipecg_distributed(As, b_sh, inv_sh, mesh=mesh1, method="h3",
                         atol=1e-6, maxiter=300)
ref_it = int(ref.iterations)
assert bool(ref.converged)
band = max(2, (ref_it + 9) // 10)  # the 10% iteration band (min 2 its)

# method x reducer matrix; None = the method's registered default
cases = [
    ("h1", None, mesh1), ("h1", "packed", mesh1),
    ("h2", None, mesh1), ("h2", "separate", mesh1),
    ("h3", None, mesh1), ("h3", "h4", mesh2),
    ("h4", None, mesh2),
    ("pl2", None, mesh1), ("pl2", "h4", mesh2), ("pl2", "separate", mesh1),
    ("pl3", None, mesh1), ("pl3", "h4", mesh2),
]
for method, reducer, mesh in cases:
    res = pipecg_distributed(As, b_sh, inv_sh, mesh=mesh, method=method,
                             reducer=reducer, atol=1e-6, maxiter=300,
                             replace_every=50)
    x = unshard_vector(res.x, bounds)
    tag = f"{method}+{reducer or 'default'}"
    assert bool(res.converged), tag
    assert abs(int(res.iterations) - ref_it) <= band, (tag, int(res.iterations), ref_it)
    true_res = float(jnp.linalg.norm(b - spmv(A, x)))
    assert true_res < 1e-3, (tag, true_res)
    err = float(jnp.linalg.norm(x - xstar))
    assert err < 1e-3, (tag, err)
    print("OK", tag, int(res.iterations), f"{true_res:.2e}")
"""


_CENSUS_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import jacobi
from repro.core.distributed import (make_solver_mesh, build_distributed_solver,
                                    get_method)
from repro.kernels.common import while_body_jaxpr, count_primitive
from repro.sparse import balanced_rows, synthetic_spd_dia, shard_dia, shard_vector
assert jax.device_count() == 8

A = synthetic_spd_dia(512, 9.0, seed=3, bandwidth=16)
inv = jacobi(A).inv_diag
bounds = balanced_rows(A.n, 8)
As = shard_dia(A, bounds)
b_sh = shard_vector(jnp.ones(A.n, jnp.float32), bounds)
inv_sh = shard_vector(inv, bounds)
mesh1 = make_solver_mesh(8)
mesh2 = make_solver_mesh(8, sub=4)

# (method, mesh) -> expected (psum-per-body, ppermute-per-body) in the
# while body. psum bounds are the schedule contract:
#   h1 = 3 separate; h2/h3 = 1 packed; h4 = 2 (intra-pod + inter-pod);
#   pl2/pl3 = 1 Gram reduction per *l* iterations -> <= 1 per l.
expect = {
    "h1": (3, 0), "h2": (1, 0), "h3": (1, 2), "h4": (2, 2),
    "pl2": (1, 6), "pl3": (1, 10),  # halo: 2 ppermutes x (2l-1) SPMVs
}
for method, mesh in [("h1", mesh1), ("h2", mesh1), ("h3", mesh1),
                     ("h4", mesh2), ("pl2", mesh1), ("pl3", mesh1)]:
    runner = build_distributed_solver(As, mesh=mesh, method=method, maxiter=50)
    closed = jax.make_jaxpr(lambda b, iv, a, r: runner(b, iv, a, r))(
        b_sh, inv_sh, jnp.float32(1e-6), jnp.float32(0.0))
    body = while_body_jaxpr(closed.jaxpr)
    ps = count_primitive(body, "psum")
    pp = count_primitive(body, "ppermute")
    eps, epp = expect[method]
    assert ps == eps, (method, "psum", ps, eps)
    assert pp == epp, (method, "ppermute", pp, epp)
    l = get_method(method).pipeline_depth
    if l > 1:  # the acceptance criterion: <= 1 reduction per l iterations
        assert ps <= 1, (method, "deep body must hold ONE global reduction")
    print("OK", method, "psum", ps, "ppermute", pp, "depth", l)
"""


_MULTIHOP_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import jacobi, pipecg
from repro.core.distributed import make_solver_mesh, pipecg_distributed
from repro.sparse import (balanced_rows, synthetic_spd_dia, shard_dia,
                          shard_vector, spmv, unshard_vector)
assert jax.device_count() == 8

# bandwidth 16 on 8-row shards: halo reaches 2 neighbors per side (hops=2)
A = synthetic_spd_dia(64, 9.0, seed=5, bandwidth=16)
M = jacobi(A)
b = jnp.asarray(np.random.default_rng(0).standard_normal(A.n), dtype=jnp.float32)
bounds = balanced_rows(A.n, 8)
As = shard_dia(A, bounds)
assert As.bandwidth > As.rows_max  # the regression precondition
ref = pipecg(A, b, M=M, atol=1e-6, maxiter=300)
mesh = make_solver_mesh(8)
for method in ("h3", "pl2"):
    res = pipecg_distributed(As, shard_vector(b, bounds),
                             shard_vector(M.inv_diag, bounds),
                             mesh=mesh, method=method, atol=1e-6, maxiter=300)
    x = unshard_vector(res.x, bounds)
    assert bool(res.converged), method
    true_res = float(jnp.linalg.norm(b - spmv(A, x)))
    assert true_res < 1e-3, (method, true_res)
    err = float(jnp.linalg.norm(x - ref.x) / jnp.linalg.norm(ref.x))
    assert err < 1e-3, (method, err)
    print("OK", method, int(res.iterations), f"{true_res:.2e}")
"""


_BATCHED_CODE = """
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.plan import get_plan, clear_plan_cache
from repro.sparse import synthetic_spd_dia, spmv
assert jax.device_count() == 8

A = synthetic_spd_dia(512, 9.0, seed=3, bandwidth=16)
rng = np.random.default_rng(0)
B = jnp.asarray(rng.standard_normal((4, A.n)), dtype=jnp.float32)

p = repro.plan(A, method="pl2", shards=8, atol=1e-6, maxiter=300, replace_every=50)
t0 = p.trace_count
res = p.solve_batched(B)
t1 = p.trace_count
assert t1 - t0 == 1, (t0, t1, "batched solve must be ONE traced program")
res2 = p.solve_batched(B)
assert p.trace_count == t1, "second batch of same size must not retrace"
assert res.x.shape == B.shape
for k in range(B.shape[0]):
    r = float(jnp.linalg.norm(B[k] - spmv(A, res.x[k])))
    assert r < 1e-3, (k, r)
singles = [p.solve(B[k]) for k in range(B.shape[0])]
for k, s in enumerate(singles):
    assert int(res.iterations[k]) == int(s.iterations), (k, "batched lane differs")

d = p.describe()
assert d["pipeline_depth"] == 2 and d["replace_every"] == 50, d
assert d["reducer"] == "packed" and d["spmv_strategy"] == "halo", d

# plan-cache separation: the new knobs are part of the key
clear_plan_cache()
p1 = get_plan(A, method="h3", shards=8)
p2 = get_plan(A, method="pl2", shards=8)
p3 = get_plan(A, method="pl2", shards=8, replace_every=50)
p4 = get_plan(A, method="h4", shards=8, sub=4)
p5 = get_plan(A, method="pl2", shards=8)
assert len({id(p1), id(p2), id(p3), id(p4)}) == 4, "plan-cache key collision"
assert p5 is p2, "identical config must hit the cache"
assert get_plan(A, method="h4", shards=8, sub=4).describe()["sub"] == 4
print("OK batched traces", t1 - t0, "iters", np.asarray(res.iterations))
"""


class TestEquivalenceMatrix:
    def test_method_reducer_matrix(self):
        out = run_multidevice(_MATRIX_CODE, 8)
        assert out.count("OK") == 12, out


class TestCollectiveCensus:
    def test_reductions_per_iteration(self):
        out = run_multidevice(_CENSUS_CODE, 8)
        assert out.count("OK") == 6, out


class TestMultiHopHalo:
    def test_band_wider_than_shard(self):
        out = run_multidevice(_MULTIHOP_CODE, 8)
        assert out.count("OK") == 2, out


class TestBatchedSingleProgram:
    def test_one_trace_per_batch_size(self):
        out = run_multidevice(_BATCHED_CODE, 8)
        assert "OK batched traces 1" in out, out
