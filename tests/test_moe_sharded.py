"""Sharded MoE dispatch (moe_ffn_sharded) vs the pjit baseline.

The §Perf cell-1 fix: device-local dispatch + one psum. Equality gate runs
on an 8-device subprocess mesh with no-drop capacity so routing matches.
"""
import numpy as np
import pytest

from conftest import run_multidevice

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from dataclasses import replace
from repro.compat import AxisType, make_mesh
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.common import use_sharding_rules
from repro.launch.sharding import DEFAULT_RULES, make_resolver

mesh = make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
cfg = reduced(get_config("{arch}"))
cfg = replace(cfg, moe_capacity_factor=float(cfg.n_experts))
api = build_model(cfg)
params = api.init_params(jax.random.PRNGKey(0))
batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)}}
l1, a1 = api.forward(params, batch)  # baseline pjit path (no mesh context)
resolver = make_resolver(mesh, DEFAULT_RULES())
with mesh, use_sharding_rules(resolver, mesh):
    l2, a2 = jax.jit(lambda p, b: api.forward(p, b))(params, batch)
d = float(jnp.max(jnp.abs(l1 - l2)))
assert d < 2e-3, d
# aux differs by estimator (per-shard stats vs global); same ballpark only
assert 0.5 < float(a2) / max(float(a1), 1e-9) < 2.0, (float(a1), float(a2))
print("OK", d)
"""


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "granite-moe-1b-a400m"])
def test_sharded_moe_matches_baseline(arch):
    out = run_multidevice(_CODE.format(arch=arch), n_devices=8, timeout=900)
    assert "OK" in out
