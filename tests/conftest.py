"""Shared test fixtures.

NOTE: XLA_FLAGS / device-count manipulation is deliberately NOT done here —
smoke tests and benches must see the real single CPU device. Multi-device
tests spawn subprocesses that set XLA_FLAGS before importing jax.
"""
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _no_nan_debug():
    # keep default flags; placeholder for future global toggles
    yield


def assert_allclose(a, b, rtol=1e-5, atol=1e-6):
    np.testing.assert_allclose(
        np.asarray(a, dtype=np.float64), np.asarray(b, dtype=np.float64), rtol=rtol, atol=atol
    )


SUBPROCESS_ENV = dict(os.environ)
SUBPROCESS_ENV.pop("XLA_FLAGS", None)


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600):
    """Run `code` in a subprocess with n virtual CPU devices."""
    import subprocess

    env = dict(SUBPROCESS_ENV)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=timeout, env=env
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
        )
    return proc.stdout
