"""Solver correctness: PCG (Alg 1) vs Chronopoulos vs PIPECG (Alg 2).

The paper's evaluation is speedup-only because PIPECG is algebraically
equivalent to PCG — that equivalence is the correctness gate here: same
solutions, same iteration counts (within finite-precision drift), matching
residual histories.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based tests are optional: skip them, not the module
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import block_jacobi, chronopoulos_cg, identity, jacobi, pcg, pipecg
from repro.sparse import poisson27, poisson125, spmv, synthetic_spd_dia, table1_matrix


def _system(A):
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)  # paper §VI: exact solution 1/sqrt(N)
    b = spmv(A, xstar)
    return xstar, b


SOLVERS = {"pcg": pcg, "chronopoulos": chronopoulos_cg, "pipecg": pipecg}


class TestConvergence:
    @pytest.mark.parametrize("solver", list(SOLVERS))
    def test_poisson27_jacobi(self, solver):
        A = poisson27(8)
        xstar, b = _system(A)
        res = SOLVERS[solver](A, b, M=jacobi(A), atol=1e-6, maxiter=1000)
        assert bool(res.converged)
        assert float(jnp.linalg.norm(res.x - xstar)) < 1e-4

    @pytest.mark.parametrize("solver", list(SOLVERS))
    def test_poisson125(self, solver):
        A = poisson125(6)
        xstar, b = _system(A)
        res = SOLVERS[solver](A, b, M=jacobi(A), atol=1e-6, maxiter=1000)
        assert bool(res.converged)
        assert float(jnp.linalg.norm(res.x - xstar)) < 1e-4

    def test_identity_pc(self):
        A = poisson27(6)
        xstar, b = _system(A)
        res = pipecg(A, b, M=identity(), atol=1e-6, maxiter=1000)
        assert bool(res.converged)

    def test_block_jacobi_at_least_as_fast(self):
        A = synthetic_spd_dia(256, 9.0, seed=2)
        xstar, b = _system(A)
        rj = pipecg(A, b, M=jacobi(A), atol=1e-6, maxiter=2000)
        rb = pipecg(A, b, M=block_jacobi(A, block=4), atol=1e-6, maxiter=2000)
        assert bool(rb.converged)
        assert int(rb.iterations) <= int(rj.iterations) + 2

    def test_rtol_mode(self):
        A = poisson27(6)
        _, b = _system(A)
        res = pcg(A, b, M=jacobi(A), atol=0.0, rtol=1e-6, maxiter=1000)
        assert bool(res.converged)


class TestEquivalence:
    """PIPECG must track PCG: same math, different schedule."""

    @pytest.mark.parametrize("gen", [lambda: poisson27(7), lambda: synthetic_spd_dia(400, 9.0, seed=11)])
    def test_iteration_counts_match(self, gen):
        A = gen()
        xstar, b = _system(A)
        M = jacobi(A)
        its = {k: int(s(A, b, M=M, atol=1e-6, maxiter=2000).iterations) for k, s in SOLVERS.items()}
        assert max(its.values()) - min(its.values()) <= 2, its

    def test_residual_histories_track(self):
        A = poisson27(7)
        xstar, b = _system(A)
        M = jacobi(A)
        h_pcg = np.asarray(pcg(A, b, M=M, atol=1e-6, maxiter=100).history)
        h_pipe = np.asarray(pipecg(A, b, M=M, atol=1e-6, maxiter=100).history)
        k = min(np.count_nonzero(~np.isnan(h_pcg)), np.count_nonzero(~np.isnan(h_pipe)))
        assert k > 3
        # same convergence trajectory within finite-precision drift
        np.testing.assert_allclose(h_pcg[: k - 1], h_pipe[: k - 1], rtol=0.15)

    def test_solutions_match_f32(self):
        """In float32 PIPECG's recurrence-residual drifts (known finite-
        precision property); solutions must still agree to ~1e-2 and both
        must have small TRUE residuals."""
        A = synthetic_spd_dia(300, 7.0, seed=12)
        xstar, b = _system(A)
        M = jacobi(A)
        xs = {}
        for k, s in SOLVERS.items():
            res = s(A, b, M=M, atol=1e-6, maxiter=3000)
            xs[k] = np.asarray(res.x)
            true_res = float(jnp.linalg.norm(b - spmv(A, res.x)))
            assert true_res < 1e-3, (k, true_res)
        np.testing.assert_allclose(xs["pcg"], xs["pipecg"], rtol=2e-2, atol=1e-4)
        np.testing.assert_allclose(xs["pcg"], xs["chronopoulos"], rtol=2e-2, atol=1e-4)

    def test_residual_replacement_arrests_drift(self):
        """Beyond-paper: with replace_every, long f32 runs at unattainable
        tolerance must NOT diverge (plain PIPECG recurrences do)."""
        A = synthetic_spd_dia(300, 7.0, seed=12)
        xstar, b = _system(A)
        M = jacobi(A)
        plain = pipecg(A, b, M=M, atol=0.0, maxiter=300)
        rr = pipecg(A, b, M=M, atol=0.0, maxiter=300, replace_every=25)
        true_plain = float(jnp.linalg.norm(b - spmv(A, plain.x)))
        true_rr = float(jnp.linalg.norm(b - spmv(A, rr.x)))
        assert true_rr < 5e-4, true_rr
        assert true_rr < true_plain

    def test_solutions_match_f64(self):
        """Under float64 the algebraic equivalence is near-exact."""
        from repro.compat import enable_x64

        with enable_x64():
            A = synthetic_spd_dia(200, 7.0, seed=13, dtype=jnp.float64)
            xstar = jnp.ones((200,), jnp.float64) / jnp.sqrt(200.0)
            b = spmv(A, xstar)
            M = jacobi(A)
            xs = {k: np.asarray(s(A, b, M=M, atol=1e-10, maxiter=3000).x) for k, s in SOLVERS.items()}
        np.testing.assert_allclose(xs["pcg"], xs["pipecg"], rtol=1e-6, atol=1e-9)
        np.testing.assert_allclose(xs["pcg"], xs["chronopoulos"], rtol=1e-6, atol=1e-9)

    def test_pallas_engine_matches_jnp(self):
        A = poisson27(7)
        xstar, b = _system(A)
        M = jacobi(A)
        r1 = pipecg(A, b, M=M, atol=1e-6, maxiter=500, engine="jnp")
        r2 = pipecg(A, b, M=M, atol=1e-6, maxiter=500, engine="pallas")
        assert abs(int(r1.iterations) - int(r2.iterations)) <= 1
        np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x), rtol=1e-4, atol=1e-5)


class TestEdgeCases:
    def test_zero_rhs(self):
        A = poisson27(5)
        b = jnp.zeros((A.n,))
        res = pipecg(A, b, M=jacobi(A), atol=1e-6, maxiter=100)
        assert bool(res.converged)
        assert int(res.iterations) == 0
        assert float(jnp.linalg.norm(res.x)) == 0.0

    def test_maxiter_exhaustion(self):
        A = poisson125(5)
        _, b = _system(A)
        res = pipecg(A, b, M=identity(), atol=1e-30, maxiter=3)
        assert not bool(res.converged)
        assert int(res.iterations) == 3

    def test_warm_start(self):
        A = poisson27(6)
        xstar, b = _system(A)
        res = pipecg(A, b, M=jacobi(A), x0=xstar, atol=1e-6, maxiter=100)
        assert int(res.iterations) <= 1

    def test_history_shape_and_nan_padding(self):
        A = poisson27(5)
        _, b = _system(A)
        res = pcg(A, b, M=jacobi(A), atol=1e-6, maxiter=50)
        h = np.asarray(res.history)
        assert h.shape == (51,)
        k = int(res.iterations)
        assert np.all(np.isnan(h[k + 1 :]))
        assert not np.any(np.isnan(h[: k + 1]))


if HAVE_HYPOTHESIS:

    @st.composite
    def spd_problem(draw):
        n = draw(st.integers(min_value=32, max_value=300))
        nnz = draw(st.floats(min_value=3.0, max_value=15.0))
        seed = draw(st.integers(min_value=0, max_value=2**16))
        return n, nnz, seed

    class TestProperties:
        """Property-based invariants of the solver family (hypothesis)."""

        @settings(max_examples=15, deadline=None)
        @given(spd_problem())
        def test_pipecg_solves_random_spd(self, prob):
            n, nnz, seed = prob
            A = synthetic_spd_dia(n, nnz, seed=seed)
            xstar = jnp.ones((n,)) / jnp.sqrt(n)
            b = spmv(A, xstar)
            # paper's tolerance (1e-5), made scale-relative; residual
            # replacement keeps f32 recurrences honest on adversarial
            # instances
            res = pipecg(A, b, M=jacobi(A), atol=0.0, rtol=1e-5, maxiter=5 * n, replace_every=50)
            assert bool(res.converged)
            true_rel = float(jnp.linalg.norm(b - spmv(A, res.x)) / jnp.linalg.norm(b))
            assert true_rel < 1e-3

        @settings(max_examples=10, deadline=None)
        @given(spd_problem())
        def test_monotone_energy_norm(self, prob):
            """CG minimizes the A-norm of the error over the Krylov space:
            the error must be (weakly) monotone decreasing in the A-norm."""
            n, nnz, seed = prob
            A = synthetic_spd_dia(n, nnz, seed=seed)
            xstar = jnp.ones((n,)) / jnp.sqrt(n)
            b = spmv(A, xstar)
            hist = []
            # run a few manual restarts to sample intermediate errors
            for it in (1, 2, 4, 8, 16):
                res = pcg(A, b, M=jacobi(A), atol=0.0, maxiter=it)
                e = res.x - xstar
                hist.append(float(jnp.dot(e, spmv(A, e))))
            for a, c in zip(hist, hist[1:]):
                assert c <= a * (1 + 1e-3)

        @settings(max_examples=10, deadline=None)
        @given(st.integers(min_value=0, max_value=2**16))
        def test_pcg_pipecg_same_iterations(self, seed):
            A = synthetic_spd_dia(128, 7.0, seed=seed)
            xstar = jnp.ones((128,)) / jnp.sqrt(128.0)
            b = spmv(A, xstar)
            M = jacobi(A)
            i1 = int(pcg(A, b, M=M, atol=1e-6, maxiter=1000).iterations)
            i2 = int(pipecg(A, b, M=M, atol=1e-6, maxiter=1000).iterations)
            assert abs(i1 - i2) <= 2

else:

    class TestProperties:
        @pytest.mark.skip(reason="hypothesis not installed")
        def test_property_based(self):
            pass
