"""The unified solver/operator architecture.

Covers the three strategy axes of the shared iteration core
(``core.iteration.run_pipecg``):

* SPMV engine dispatch — Pallas-vs-jnp parity for DIA and BELL
  (interpret mode on CPU), dense fallback, registry extension;
* the ``repro.solve`` registry — every method converges through one
  entry point, ``engine="pallas"`` runs core + SPMV on the kernels;
* cross-strategy equivalence — single-device ``pipecg`` and distributed
  h1/h2/h3 produce matching iterates because they run the same core.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidevice

import repro
from repro.sparse import (
    DIAMatrix,
    bell_from_csr,
    csr_from_dia,
    poisson27,
    register_spmv,
    spmv,
    spmv_engines,
    synthetic_spd_dia,
)


def _system(A):
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
    return xstar, spmv(A, xstar)


class TestSpmvDispatch:
    """Engine registry: (format, engine) -> kernel, with jnp fallback."""

    @pytest.mark.parametrize("gen", [lambda: poisson27(7), lambda: synthetic_spd_dia(500, 9.0, seed=4)])
    def test_dia_pallas_matches_jnp(self, gen):
        A = gen()
        x = jnp.asarray(np.random.default_rng(0).normal(size=(A.n,)), jnp.float32)
        y_j = np.asarray(spmv(A, x, engine="jnp"), np.float64)
        y_p = np.asarray(spmv(A, x, engine="pallas"), np.float64)
        np.testing.assert_allclose(y_p, y_j, rtol=1e-5, atol=1e-4)

    def test_bell_pallas_matches_jnp(self):
        A = bell_from_csr(csr_from_dia(poisson27(6)))
        x = jnp.asarray(np.random.default_rng(1).normal(size=(A.n,)), jnp.float32)
        y_j = np.asarray(spmv(A, x, engine="jnp"), np.float64)
        y_p = np.asarray(spmv(A, x, engine="pallas"), np.float64)
        np.testing.assert_allclose(y_p, y_j, rtol=1e-5, atol=1e-4)

    def test_dense_fallback(self):
        A = jnp.eye(16) * 2.0
        x = jnp.arange(16.0)
        # dense has no pallas engine: request must fall back to jnp
        np.testing.assert_allclose(np.asarray(spmv(A, x, engine="pallas")), 2.0 * np.arange(16.0))

    def test_engines_listed(self):
        assert set(spmv_engines(poisson27(4))) == {"jnp", "pallas", "bf16"}
        assert spmv_engines(jnp.eye(4)) == ("jnp",)

    def test_registry_extension(self):
        class TaggedDIA(DIAMatrix):
            pass

        calls = []

        def custom(A, x):
            calls.append(1)
            return x

        register_spmv(TaggedDIA, "custom", custom)
        A = poisson27(4)
        T = TaggedDIA(A.data, A.offsets, A.n)
        x = jnp.ones((A.n,))
        # the custom engine dispatches; MRO still finds DIA's jnp engine
        np.testing.assert_allclose(np.asarray(spmv(T, x, engine="custom")), np.asarray(x))
        assert calls
        np.testing.assert_allclose(
            np.asarray(spmv(T, x, engine="jnp")), np.asarray(spmv(A, x, engine="jnp"))
        )


class TestSolveRegistry:
    @pytest.mark.parametrize("method", ["pcg", "chronopoulos", "pipecg"])
    def test_single_device_methods(self, method):
        A = poisson27(7)
        xstar, b = _system(A)
        res = repro.solve(A, b, method=method, M="jacobi", atol=1e-6, maxiter=500)
        assert bool(res.converged)
        assert float(jnp.linalg.norm(res.x - xstar)) < 1e-4

    def test_pipecg_pallas_engine_converges(self):
        """Acceptance: repro.solve(A, b, method='pipecg', engine='pallas')
        runs the fused VMA core AND the Pallas SPMV through the shared
        core and still converges on a Poisson matrix."""
        A = poisson27(7)
        xstar, b = _system(A)
        res = repro.solve(A, b, method="pipecg", engine="pallas", M="jacobi", atol=1e-6, maxiter=500)
        assert bool(res.converged)
        assert float(jnp.linalg.norm(res.x - xstar)) < 1e-4
        ref = repro.solve(A, b, method="pipecg", engine="jnp", M="jacobi", atol=1e-6, maxiter=500)
        assert abs(int(res.iterations) - int(ref.iterations)) <= 1
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x), rtol=1e-4, atol=1e-5)

    def test_unknown_method_raises(self):
        A = poisson27(4)
        _, b = _system(A)
        with pytest.raises(ValueError, match="unknown method"):
            repro.solve(A, b, method="does-not-exist")

    def test_register_solver_extension(self):
        from repro.core.types import SolveResult

        def diag_solve(A, b, *, M, x0, atol, rtol, maxiter, engine, **_):
            x = b / A.diagonal()
            z = jnp.zeros(())
            return SolveResult(
                x=x, iterations=jnp.int32(1), residual_norm=z,
                converged=jnp.bool_(True), history=jnp.zeros((maxiter + 1,)),
            )

        repro.register_solver("diag", diag_solve)
        assert "diag" in repro.solver_names()
        A = poisson27(4)
        _, b = _system(A)
        res = repro.solve(A, b, method="diag")
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(b / A.diagonal()))

    def test_solver_engine_batches(self):
        from repro.serve.engine import SolverEngine

        A = poisson27(6)
        eng = SolverEngine(A, method="pipecg", atol=0.0, rtol=1e-5, maxiter=300)
        xs = jnp.stack([jnp.sin(jnp.arange(A.n) * (k + 1) / 7.0) for k in range(3)])
        bs = jnp.stack([spmv(A, x) for x in xs])
        rb = eng.solve_batch(bs)
        assert rb.x.shape == bs.shape
        for k in range(3):
            assert bool(rb.converged[k])
            rel = float(jnp.linalg.norm(bs[k] - spmv(A, rb.x[k])) / jnp.linalg.norm(bs[k]))
            assert rel < 1e-3


_CROSS_STRATEGY = """
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.sparse import poisson27, spmv
assert jax.device_count() == 4, jax.device_count()

A = poisson27(10)
xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
b = spmv(A, xstar)
ref = repro.solve(A, b, method="pipecg", engine="jnp", M="jacobi", atol=1e-6, maxiter=500)
h_ref = np.asarray(ref.history)
k_ref = int(ref.iterations)
for method in ("h1", "h2", "h3"):
    res = repro.solve(A, b, method=method, M="jacobi", shards=4, atol=1e-6, maxiter=500)
    assert bool(res.converged), method
    assert abs(int(res.iterations) - k_ref) <= 1, (method, int(res.iterations), k_ref)
    # same core => same residual trajectory (up to psum summation order)
    k = min(int(res.iterations), k_ref)
    np.testing.assert_allclose(np.asarray(res.history)[:k], h_ref[:k], rtol=5e-2)
    err = float(jnp.linalg.norm(res.x - ref.x))
    assert err < 1e-4, (method, err)
print("OK", k_ref)
"""


class TestCrossStrategy:
    def test_distributed_matches_single_device_iterates(self):
        """Single-device pipecg and h1/h2/h3 run the SAME iteration core;
        their residual histories and solutions must coincide."""
        out = run_multidevice(_CROSS_STRATEGY, n_devices=4)
        assert "OK" in out


class TestCompat:
    def test_shim_exports(self):
        from repro.compat import AxisType, make_mesh, shard_map

        assert callable(shard_map)
        assert hasattr(AxisType, "Auto")
        mesh = make_mesh((1,), ("x",), devices=jax.devices()[:1],
                         axis_types=(AxisType.Auto,))
        assert tuple(mesh.axis_names) == ("x",)
