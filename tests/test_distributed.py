"""Distributed PIPECG: h1/h2/h3 schedules on multi-device (virtual) meshes.

Multi-device cases run in subprocesses with XLA_FLAGS set before jax import
(the main test process keeps the real single-device view).
"""
import numpy as np
import pytest

from conftest import run_multidevice

# Single-process (P=1) sanity: the distributed path degenerates correctly.
import jax
import jax.numpy as jnp

from repro.core import jacobi, pipecg
from repro.core.distributed import make_solver_mesh, pipecg_distributed
from repro.core.perfmodel import StragglerTracker, decompose, relative_weights
from repro.sparse import (
    balanced_rows,
    poisson27,
    shard_dia,
    shard_vector,
    spmv,
    synthetic_spd_dia,
    unshard_vector,
)


class TestSingleShard:
    @pytest.mark.parametrize("method", ["h1", "h2", "h3"])
    def test_p1_matches_single_device(self, method):
        A = poisson27(6)
        xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
        b = spmv(A, xstar)
        bounds = balanced_rows(A.n, 1)
        As = shard_dia(A, bounds)
        mesh = make_solver_mesh(1)
        inv = shard_vector(jacobi(A).inv_diag, bounds)
        res = pipecg_distributed(
            As, shard_vector(b, bounds), inv, mesh=mesh, method=method, atol=1e-6, maxiter=500
        )
        x = unshard_vector(res.x, bounds)
        ref = pipecg(A, b, M=jacobi(A), atol=1e-6, maxiter=500)
        assert bool(res.converged)
        assert abs(int(res.iterations) - int(ref.iterations)) <= 1
        np.testing.assert_allclose(np.asarray(x), np.asarray(ref.x), rtol=1e-3, atol=1e-5)


_MULTI_TEMPLATE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import jacobi, pipecg
from repro.core.distributed import make_solver_mesh, pipecg_distributed
from repro.core.perfmodel import decompose
from repro.sparse import (balanced_rows, synthetic_spd_dia, poisson27, shard_dia,
                          shard_vector, spmv, unshard_vector)
assert jax.device_count() == {P}, jax.device_count()

A = {matrix}
xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
b = spmv(A, xstar)
M = jacobi(A)
bounds = {bounds}
As = shard_dia(A, bounds)
mesh = make_solver_mesh({P})
res = pipecg_distributed(As, shard_vector(b, bounds), shard_vector(M.inv_diag, bounds),
                         mesh=mesh, method={method!r}, atol=1e-6, maxiter=1000)
x = unshard_vector(res.x, bounds)
ref = pipecg(A, b, M=M, atol=1e-6, maxiter=1000)
assert bool(res.converged), "did not converge"
assert abs(int(res.iterations) - int(ref.iterations)) <= 2, (int(res.iterations), int(ref.iterations))
err = float(jnp.linalg.norm(x - ref.x))
assert err < 1e-3, err
true_res = float(jnp.linalg.norm(b - spmv(A, x)))
assert true_res < 1e-3, true_res
print("OK", int(res.iterations), err)
"""


class TestMultiShard:
    @pytest.mark.parametrize("method", ["h1", "h2", "h3"])
    def test_poisson_8way(self, method):
        out = run_multidevice(
            _MULTI_TEMPLATE.format(
                P=8, matrix="poisson27(12)", bounds="balanced_rows(A.n, 8)", method=method
            ),
            n_devices=8,
        )
        assert "OK" in out

    @pytest.mark.parametrize("method", ["h2", "h3"])
    def test_synthetic_4way(self, method):
        out = run_multidevice(
            _MULTI_TEMPLATE.format(
                P=4,
                matrix="synthetic_spd_dia(1000, 9.0, seed=3, bandwidth=16)",
                bounds="balanced_rows(A.n, 4)",
                method=method,
            ),
            n_devices=4,
        )
        assert "OK" in out

    def test_h3_weighted_partition(self):
        """The paper's performance-model (unequal) decomposition, h3 only."""
        code = _MULTI_TEMPLATE.format(
            P=4,
            matrix="synthetic_spd_dia(1200, 7.0, seed=5, bandwidth=12)",
            bounds="decompose(A, 4, weights=np.array([2.0, 1.0, 1.0, 1.0]))",
            method="h3",
        )
        out = run_multidevice(code, n_devices=4)
        assert "OK" in out

    def test_h1_rejects_unequal(self):
        with pytest.raises(AssertionError, match="equal shards"):
            run_multidevice(
                _MULTI_TEMPLATE.format(
                    P=4,
                    matrix="synthetic_spd_dia(1200, 7.0, seed=5, bandwidth=12)",
                    bounds="np.array([0, 200, 500, 900, 1200])",
                    method="h1",
                ),
                n_devices=4,
            )


class TestPerfModel:
    def test_relative_weights(self):
        # paper: s = nnz/t; 2x slower device gets half the share
        w = relative_weights(np.array([1.0, 2.0]))
        np.testing.assert_allclose(w, [2 / 3, 1 / 3])

    def test_decompose_tracks_weights(self):
        A = synthetic_spd_dia(2000, 9.0, seed=7)
        b = decompose(A, 4, weights=np.array([3.0, 1.0, 1.0, 1.0]))
        data = np.asarray(A.data)
        row_nnz = (data != 0).sum(axis=0)
        shares = [row_nnz[b[i] : b[i + 1]].sum() for i in range(4)]
        total = sum(shares)
        assert shares[0] / total == pytest.approx(0.5, abs=0.05)

    def test_straggler_tracker(self):
        tr = StragglerTracker(n_devices=4)
        tr.update(np.array([1.0, 1.0, 1.0, 1.0]))
        assert not tr.needs_rebalance()
        for _ in range(20):
            tr.update(np.array([1.0, 1.0, 1.0, 2.0]))  # device 3 degrades
        assert tr.needs_rebalance()
        w = tr.proposed_weights()
        assert w[3] == pytest.approx(w[0] / 2, rel=0.1)

    def test_measure_spmv_time_runs(self):
        from repro.core.perfmodel import measure_spmv_time

        A = poisson27(5)
        t = measure_spmv_time(A, runs=3)
        assert t > 0
