"""Launch-layer logic: sharding rule resolution and HLO roofline parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.roofline import HloAnalysis, analyze_hlo, roofline_terms
from repro.launch.sharding import DEFAULT_RULES, resolve_spec


class FakeMesh:
    """Only .shape is consulted by resolve_spec."""

    def __init__(self, **axes):
        self.shape = dict(axes)


MESH = FakeMesh(data=16, model=16)
MESH_MP = FakeMesh(pod=2, data=16, model=16)


class TestResolveSpec:
    def test_basic_2d(self):
        spec = resolve_spec((8192, 4096), ("embed", "heads_flat"), MESH, DEFAULT_RULES())
        assert spec == P(None, "model")

    def test_batch_multi_axis(self):
        spec = resolve_spec((256, 4096), ("batch", None), MESH_MP, DEFAULT_RULES())
        assert spec == P(("pod", "data"), None)

    def test_batch_single_pod(self):
        spec = resolve_spec((256, 4096), ("batch", None), MESH, DEFAULT_RULES())
        assert spec == P("data", None)

    def test_nondivisible_dropped_and_logged(self):
        rules = DEFAULT_RULES()
        # whisper: vocab 51865 % 16 != 0 -> replicate + log
        spec = resolve_spec((51865, 384), ("vocab", "embed"), MESH, rules)
        assert spec == P(None, None)
        assert rules.dropped, "fallback must be recorded"

    def test_batch_prefix_fallback(self):
        # batch=2 divides pod(2) but not pod*data(32): use the prefix
        spec = resolve_spec((2, 64), ("batch", None), MESH_MP, DEFAULT_RULES())
        assert spec == P("pod", None)

    def test_no_duplicate_mesh_axes(self):
        # two logical axes mapping to 'model': second one must drop
        rules = DEFAULT_RULES()
        spec = resolve_spec((1024, 2048), ("vocab", "mlp"), MESH, rules)
        assert spec == P("model", None)

    def test_vocab_divisible(self):
        spec = resolve_spec((152064, 5120), ("vocab", "embed"), MESH, DEFAULT_RULES())
        assert spec == P("model", None)


_HLO = """\
HloModule test, entry_computation_layout={()->f32[8,8]{1,0}}

%wide.cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %gte = s32[] get-tuple-element(%p), index=0
  %constant.5 = s32[] constant(24)
  ROOT %cmp = pred[] compare(%gte, %constant.5), direction=LT
}

%wide.body (p2: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p2 = (s32[], f32[8,8]{1,0}) parameter(0)
  %g0 = s32[] get-tuple-element(%p2), index=0
  %g1 = f32[8,8]{1,0} get-tuple-element(%p2), index=1
  %dot.1 = f32[8,8]{1,0} dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%wide.cond
  %c1 = s32[] constant(1)
  %add.9 = s32[] add(%g0, %c1)
  ROOT %tup = (s32[], f32[8,8]{1,0}) tuple(%add.9, %ar)
}

ENTRY %main () -> f32[8,8] {
  %c0 = s32[] constant(0)
  %init = f32[8,8]{1,0} constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%c0, %init)
  %while.1 = (s32[], f32[8,8]{1,0}) while(%t0), condition=%wide.cond, body=%wide.body
  %ag = f32[8,8]{1,0} all-gather(%init), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%while.1), index=1
}
"""


class TestHloAnalysis:
    def test_trip_count_multiplies_loop_body(self):
        hl = analyze_hlo(_HLO)
        # dot: 2 * 64 * 8 flops, x24 trips
        assert hl.flops == pytest.approx(2 * 64 * 8 * 24)
        # all-reduce in body: 24 x; all-gather outside: 1x
        assert hl.coll_by_kind_count["all-reduce"] == 1
        ar_bytes = hl.coll_by_kind_bytes["all-reduce"]
        assert ar_bytes == pytest.approx(2 * 256 * (15 / 16) * 24)
        ag_bytes = hl.coll_by_kind_bytes["all-gather"]
        assert ag_bytes == pytest.approx(256 * 15 / 16)

    def test_free_ops_not_counted(self):
        hl = analyze_hlo(_HLO)
        for op in ("tuple", "get-tuple-element", "parameter", "constant"):
            assert op not in hl.bytes_by_op, hl.bytes_by_op

    def test_real_lowering_census(self):
        """End-to-end on a real jit: matmul + psum over 8 host devices is
        too heavy here (1 device), so just validate single-device text."""
        def f(x, w):
            return jax.nn.relu(x @ w).sum()

        lowered = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32), jax.ShapeDtypeStruct((64, 64), jnp.float32)
        )
        hl = analyze_hlo(lowered.compile().as_text())
        assert hl.flops >= 2 * 64 * 64 * 64
        assert hl.hbm_bytes > 0

    def test_roofline_terms(self):
        t = roofline_terms(197e12, 819e9 * 2, 50e9 * 3)
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["memory_s"] == pytest.approx(2.0)
        assert t["collective_s"] == pytest.approx(3.0)
        assert t["dominant"] == "collective"
        assert t["bound_s"] == pytest.approx(3.0)


class TestAnalytic:
    def test_param_count_matches_layout(self):
        from repro.configs import get_config
        from repro.launch.analytic import active_param_count, param_count

        n = param_count(get_config("internlm2-1.8b"))
        assert 1.7e9 < n < 2.1e9, n  # "1.8b"
        # MoE active < total
        cfg = get_config("olmoe-1b-7b")
        assert active_param_count(cfg) < param_count(cfg)
        assert 6.0e9 < param_count(cfg) < 8.0e9

    def test_model_flops_kinds(self):
        from repro.configs import SHAPES, get_config
        from repro.launch.analytic import model_flops_simple

        cfg = get_config("stablelm-1.6b")
        f_train = model_flops_simple(cfg, SHAPES["train_4k"])
        f_decode = model_flops_simple(cfg, SHAPES["decode_32k"])
        assert f_train > 1e15
        assert f_decode < f_train / 1e4

    def test_detailed_flops_all_archs(self):
        from repro.configs import SHAPES, get_config, list_configs
        from repro.launch.analytic import analytic_flops, model_flops_simple

        for name in list_configs():
            cfg = get_config(name)
            for shp in ("train_4k", "decode_32k"):
                det = analytic_flops(cfg, SHAPES[shp])
                simple = model_flops_simple(cfg, SHAPES[shp])
                assert det > 0
                # detailed includes attention extras; same order of magnitude
                assert det > 0.3 * simple, (name, shp, det, simple)
