"""Sparse substrate tests: formats, conversions, stencils, partitioning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import (
    TABLE1,
    balanced_nnz,
    balanced_rows,
    bell_from_csr,
    csr_from_dia,
    dia_from_csr,
    partition_stats,
    poisson7,
    poisson27,
    poisson125,
    shard_dia,
    shard_vector,
    spmv,
    spmv_bell,
    spmv_dia,
    synthetic_spd_dia,
    table1_matrix,
    unshard_vector,
)
from repro.sparse.formats import csr_from_dense


def _dense(dia):
    return np.asarray(csr_from_dia(dia).to_dense())


class TestFormats:
    def test_dia_roundtrip_csr(self):
        A = synthetic_spd_dia(64, 7.0, seed=3)
        csr = csr_from_dia(A)
        A2 = dia_from_csr(csr)
        np.testing.assert_allclose(_dense(A), _dense(A2))

    def test_bell_matches_dia(self):
        A = synthetic_spd_dia(96, 9.0, seed=4)
        B = bell_from_csr(csr_from_dia(A))
        x = jax.random.normal(jax.random.PRNGKey(0), (96,))
        np.testing.assert_allclose(np.asarray(spmv_bell(B, x)), np.asarray(spmv_dia(A, x)), rtol=1e-5, atol=1e-5)

    def test_diagonal_extraction(self):
        A = synthetic_spd_dia(50, 5.0, seed=5)
        B = bell_from_csr(csr_from_dia(A))
        d = np.diag(_dense(A))
        np.testing.assert_allclose(np.asarray(A.diagonal()), d)
        np.testing.assert_allclose(np.asarray(B.diagonal()), d)

    def test_csr_from_dense(self):
        A = np.array([[2.0, 1.0, 0.0], [1.0, 3.0, 0.5], [0.0, 0.5, 1.0]])
        csr = csr_from_dense(A)
        np.testing.assert_allclose(csr.to_dense(), A)
        assert csr.nnz == 7


class TestStencil:
    @pytest.mark.parametrize("gen,n,expect_diags", [(poisson7, 6, 7), (poisson27, 5, 27), (poisson125, 6, 125)])
    def test_diag_counts(self, gen, n, expect_diags):
        A = gen(n)
        assert A.n == n**3
        assert A.n_diags == expect_diags

    @pytest.mark.parametrize("gen,n", [(poisson7, 5), (poisson27, 4), (poisson125, 5)])
    def test_spd(self, gen, n):
        A = gen(n)
        Ad = _dense(A)
        np.testing.assert_allclose(Ad, Ad.T, atol=0)
        w = np.linalg.eigvalsh(Ad)
        assert w.min() > 0, f"not PD: min eig {w.min()}"

    def test_125pt_nnz_density(self):
        # paper Table II: 125-pt Poisson matrices have nnz/N ~ 120-123
        A = poisson125(12)
        assert 100 < A.nnz() / A.n <= 125

    def test_boundary_no_wraparound(self):
        # row at the grid edge must not couple to the next grid line
        A = poisson27(4)
        Ad = _dense(A)
        # point (x=3,y=0,z=0) = idx 3; its +x neighbor would wrap to idx 4 =(x=0,y=1)
        assert Ad[3, 4] == 0.0


class TestSynthetic:
    @pytest.mark.parametrize("name", ["bcsstk15", "offshore"])
    def test_table1_analogue(self, name):
        A = table1_matrix(name, scale=0.05 if name == "offshore" else 0.2)
        n_full, nnz_per_row = TABLE1[name]
        got = A.nnz() / A.n
        assert got == pytest.approx(nnz_per_row, rel=0.35)
        Ad = _dense(A) if A.n <= 2000 else None
        if Ad is not None:
            w = np.linalg.eigvalsh(Ad)
            assert w.min() > 0

    def test_symmetry(self):
        A = synthetic_spd_dia(200, 11.0, seed=6)
        Ad = _dense(A)
        np.testing.assert_allclose(Ad, Ad.T)
        assert np.linalg.eigvalsh(Ad).min() > 0


class TestPartition:
    def test_balanced_rows(self):
        b = balanced_rows(103, 4)
        assert b[0] == 0 and b[-1] == 103
        sizes = np.diff(b)
        assert sizes.max() - sizes.min() <= 1

    def test_balanced_nnz_uniform_weights(self):
        row_nnz = np.ones(100) * 5
        row_nnz[:50] = 15  # heavy top half
        b = balanced_nnz(row_nnz, 2)
        nnz0 = row_nnz[: b[1]].sum()
        nnz1 = row_nnz[b[1] :].sum()
        assert abs(nnz0 - nnz1) / (nnz0 + nnz1) < 0.1

    def test_balanced_nnz_weighted(self):
        """The paper's performance model: 3x faster device gets ~3x the nnz."""
        row_nnz = np.ones(1000) * 10
        b = balanced_nnz(row_nnz, 2, weights=np.array([3.0, 1.0]))
        assert b[1] == pytest.approx(750, abs=5)

    def test_shard_roundtrip(self):
        A = synthetic_spd_dia(256, 7.0, seed=7, bandwidth=8)
        bounds = balanced_rows(256, 4)
        sh = shard_dia(A, bounds)
        assert sh.data.shape[0] == 4
        x = jnp.arange(256.0)
        xs = shard_vector(x, bounds)
        np.testing.assert_allclose(np.asarray(unshard_vector(xs, bounds)), np.asarray(x))

    def test_shard_identity_padding(self):
        A = synthetic_spd_dia(100, 5.0, seed=8, bandwidth=4)
        bounds = np.array([0, 30, 60, 100])  # unequal; rows_max=40
        sh = shard_dia(A, bounds)
        j0 = sh.offsets.index(0)
        # padded diag rows are exactly 1
        assert np.asarray(sh.data)[0, j0, 30:].min() == 1.0

    def test_partition_stats_2d(self):
        """nnz1/nnz2 split — halo nnz must be the band crossings only."""
        A = synthetic_spd_dia(128, 5.0, seed=9, bandwidth=4)
        bounds = balanced_rows(128, 4)
        st = partition_stats(A, bounds)
        total_halo = sum(s["nnz_halo"] for s in st["shards"])
        # halo nnz bounded by 2 * bandwidth * n_diags * n_cuts
        assert 0 < total_halo <= 2 * 4 * A.n_diags * 3
