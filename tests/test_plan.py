"""The plan/execute solver API (``repro.plan`` -> ``SolverPlan``).

Covers the reuse guarantees the redesign exists for:

* plan reuse — the second (and eighth) ``plan.solve`` re-traces nothing
  (asserted via the plan's trace counter) and matches a fresh
  ``repro.solve``;
* the keyed plan cache behind one-shot ``repro.solve``;
* distributed plans — sharding/decomposition happen exactly once per
  plan, nonzero ``x0`` is solved via the shifted system;
* the ``LinearOperator`` protocol — matrix-free ``FunctionOperator``
  equivalence with the explicit ``DIAMatrix``;
* the CSR segment-sum SPMV engine and registry hygiene
  (``overwrite=False`` everywhere, ``solver_names`` unique + sorted).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import plan as plan_mod  # callable module: plan_mod(A, ...) builds a plan
from repro.plan import clear_plan_cache, get_plan, plan_cache_stats
from repro.sparse import (
    CSRMatrix,
    DIAMatrix,
    FunctionOperator,
    as_operator,
    csr_device_from_host,
    csr_from_dia,
    poisson27,
    register_spmv,
    spmv,
    spmv_engines,
)


def _system(A):
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
    return xstar, spmv(A, xstar)


class TestPlanReuse:
    def test_eight_rhs_one_trace_matches_fresh_solve(self):
        """Acceptance: 8 rhs through one plan re-trace nothing after the
        first solve and match per-call repro.solve to 1e-6."""
        clear_plan_cache()
        A = poisson27(6)
        _, b = _system(A)
        p = repro.plan(A, method="pipecg", M="jacobi", maxiter=300)
        for k in range(8):
            bk = (1.0 + 0.25 * k) * b
            res = p.solve(bk, atol=1e-6)
            ref = repro.solve(A, bk, method="pipecg", M="jacobi", atol=1e-6, maxiter=300)
            np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x), atol=1e-6)
            assert int(res.iterations) == int(ref.iterations)
        assert p.trace_count == 1

    def test_tolerance_and_x0_are_traced_not_static(self):
        A = poisson27(6)
        xstar, b = _system(A)
        p = repro.plan(A, method="pipecg", M="jacobi", maxiter=300)
        loose = p.solve(b, atol=1e-2)
        tight = p.solve(b, atol=1e-6)
        assert int(loose.iterations) < int(tight.iterations)
        warm = p.solve(b, x0=xstar, atol=1e-6)
        assert int(warm.iterations) <= 1
        p.solve(2 * b, x0=0.5 * xstar, atol=1e-6)
        # single-device plans always pass x0 as an array (zeros when None),
        # so tolerance AND warm-start changes share the ONE traced program
        assert p.trace_count == 1

    def test_solve_batched_one_program(self):
        A = poisson27(6)
        _, b = _system(A)
        p = repro.plan(A, method="pipecg", M="jacobi", maxiter=300)
        B = jnp.stack([b, 2.0 * b, -1.0 * b])
        rb = p.solve_batched(B, atol=1e-6)
        assert rb.x.shape == B.shape
        for k in range(3):
            assert bool(rb.converged[k])
            np.testing.assert_allclose(
                np.asarray(rb.x[k]), np.asarray(p.solve(B[k], atol=1e-6).x), atol=1e-6
            )
        before = p.trace_count
        p.solve_batched(0.5 * B, atol=1e-6)
        assert p.trace_count == before  # batched program traced once, reused

    def test_describe(self):
        A = poisson27(5)
        p = repro.plan(A, method="pipecg", engine="jnp", M="jacobi", maxiter=100)
        d = p.describe()
        assert d["method"] == "pipecg"
        assert d["engine"] == "jnp"
        assert d["n"] == A.n
        assert d["preconditioner"] == "JacobiPC"
        assert not d["distributed"]

    def test_unknown_method_raises(self):
        with pytest.raises(ValueError, match="unknown method"):
            repro.plan(poisson27(4), method="does-not-exist")

    def test_unknown_kwarg_raises(self):
        with pytest.raises(TypeError, match="does not accept"):
            repro.plan(poisson27(4), method="pcg", bogus_option=3)


class TestPlanCache:
    def test_solve_hits_cache(self):
        clear_plan_cache()
        A = poisson27(5)
        _, b = _system(A)
        repro.solve(A, b, method="pipecg", M="jacobi", atol=1e-5, maxiter=200)
        s0 = plan_cache_stats()
        repro.solve(A, 2 * b, method="pipecg", M="jacobi", atol=1e-6, maxiter=200)
        s1 = plan_cache_stats()
        assert s0["misses"] == 1 and s0["hits"] == 0
        assert s1["hits"] == 1 and s1["misses"] == 1  # atol change still hits
        assert get_plan(A, method="pipecg", M="jacobi", maxiter=200) is get_plan(
            A, method="pipecg", M="jacobi", maxiter=200
        )

    def test_config_change_is_a_different_plan(self):
        clear_plan_cache()
        A = poisson27(5)
        p1 = get_plan(A, method="pipecg", M="jacobi", maxiter=200)
        p2 = get_plan(A, method="pipecg", M="jacobi", maxiter=300)  # static: re-plan
        p3 = get_plan(A, method="pcg", engine="jnp", M="jacobi", maxiter=200)
        assert p1 is not p2 and p1 is not p3
        assert get_plan(A, method="pipecg", M="jacobi", maxiter=200) is p1

    def test_operator_identity_keys_the_cache(self):
        clear_plan_cache()
        A1 = poisson27(5)
        A2 = poisson27(5)  # equal values, distinct object -> distinct plan
        assert get_plan(A1, method="pipecg", maxiter=100) is not get_plan(
            A2, method="pipecg", maxiter=100
        )


class TestFunctionOperator:
    def test_matches_explicit_dia(self):
        A = poisson27(6)
        _, b = _system(A)
        op = FunctionOperator(
            fn=lambda v: spmv(A, v), n=A.n, out_dtype=b.dtype, diag=A.diagonal()
        )
        r_op = repro.solve(op, b, method="pipecg", M="jacobi", atol=1e-6, maxiter=300)
        r_dia = repro.solve(A, b, method="pipecg", M="jacobi", atol=1e-6, maxiter=300)
        assert bool(r_op.converged)
        assert int(r_op.iterations) == int(r_dia.iterations)
        np.testing.assert_allclose(np.asarray(r_op.x), np.asarray(r_dia.x), atol=1e-6)

    def test_matrix_free_without_diag_needs_non_jacobi_pc(self):
        A = poisson27(5)
        _, b = _system(A)
        op = FunctionOperator(fn=lambda v: spmv(A, v), n=A.n, out_dtype=b.dtype)
        with pytest.raises(ValueError, match="no diagonal"):
            repro.plan(op, method="pipecg", M="jacobi")
        res = repro.solve(op, b, method="pipecg", M="identity", atol=1e-6, maxiter=300)
        assert bool(res.converged)

    def test_as_operator_wraps_callables(self):
        A = poisson27(5)
        op = as_operator(lambda v: spmv(A, v), n=A.n, diag=A.diagonal())
        assert isinstance(op, FunctionOperator)
        assert op.shape == (A.n, A.n)
        assert as_operator(A) is A
        with pytest.raises(ValueError, match="needs n="):
            as_operator(lambda v: v)

    def test_spmv_protocol_fallback(self):
        A = poisson27(5)
        op = FunctionOperator(fn=lambda v: 2.0 * v, n=A.n)
        x = jnp.arange(float(A.n))
        np.testing.assert_allclose(np.asarray(spmv(op, x)), 2.0 * np.arange(A.n))
        assert spmv_engines(op) == ("jnp",)


class TestDistributedPlan:
    """shards=1 runs the full h3 machinery (shard_map, halo spmv, packed
    psum) on the default single device — multi-device equivalence is
    covered by tests/test_unified_solver.py::TestCrossStrategy."""

    def test_setup_runs_exactly_once_for_eight_rhs(self, monkeypatch):
        clear_plan_cache()
        calls = {"shard": 0, "decomp": 0}
        real_shard, real_decomp = plan_mod.shard_dia, plan_mod.decompose

        def counting_shard(*a, **k):
            calls["shard"] += 1
            return real_shard(*a, **k)

        def counting_decomp(*a, **k):
            calls["decomp"] += 1
            return real_decomp(*a, **k)

        monkeypatch.setattr(plan_mod, "shard_dia", counting_shard)
        monkeypatch.setattr(plan_mod, "decompose", counting_decomp)
        A = poisson27(8)
        _, b = _system(A)
        p = repro.plan(A, method="h3", M="jacobi", shards=1, partition="nnz", maxiter=300)
        assert calls == {"shard": 1, "decomp": 1}
        for k in range(8):
            bk = (1.0 + 0.5 * k) * b
            res = p.solve(bk, atol=1e-6)
            ref = repro.solve(A, bk, method="h3", M="jacobi", shards=1,
                              partition="nnz", atol=1e-6, maxiter=300)
            np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x), atol=1e-6)
        # 8 rhs later: still exactly one sharding/decomposition, one trace
        assert calls == {"shard": 2, "decomp": 2}  # +1 for repro.solve's own cached plan
        assert p.trace_count == 1

    def test_distributed_describe(self):
        p = repro.plan(poisson27(8), method="h3", M="jacobi", shards=1, maxiter=100)
        d = p.describe()
        assert d["distributed"] and d["method"] == "h3"
        assert d["reducer"] == "packed" and d["spmv_strategy"] == "halo"
        assert d["shard_bounds"] == (0, 512)

    def test_nonzero_x0_solves_shifted_system(self):
        A = poisson27(8)
        xstar, b = _system(A)
        warm = repro.solve(A, b, method="h3", M="jacobi", shards=1, x0=xstar,
                           atol=1e-6, maxiter=300)
        assert int(warm.iterations) <= 1
        assert float(jnp.linalg.norm(warm.x - xstar)) < 1e-5
        x0 = 0.25 * xstar
        part = repro.solve(A, b, method="h3", M="jacobi", shards=1, x0=x0,
                           atol=1e-6, maxiter=300)
        assert bool(part.converged)
        assert float(jnp.linalg.norm(part.x - xstar)) < 1e-4


class TestCSREngine:
    def _csr(self, A: DIAMatrix) -> CSRMatrix:
        return csr_device_from_host(csr_from_dia(A))

    def test_segment_sum_parity(self):
        A = poisson27(7)
        C = self._csr(A)
        x = jnp.asarray(np.random.default_rng(3).normal(size=(A.n,)), jnp.float32)
        y_dia = np.asarray(spmv(A, x), np.float64)
        y_ref = np.asarray(spmv(C, x, engine="jnp"), np.float64)
        y_seg = np.asarray(spmv(C, x, engine="segsum"), np.float64)
        np.testing.assert_allclose(y_ref, y_dia, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(y_seg, y_dia, rtol=1e-5, atol=1e-5)
        assert set(spmv_engines(C)) == {"jnp", "segsum"}

    def test_csr_solves_through_plan(self):
        A = poisson27(6)
        _, b = _system(A)
        C = self._csr(A)
        res = repro.solve(C, b, method="pipecg", M="jacobi", atol=1e-6, maxiter=300)
        ref = repro.solve(A, b, method="pipecg", M="jacobi", atol=1e-6, maxiter=300)
        assert bool(res.converged)
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x), atol=1e-5)

    def test_csr_diagonal(self):
        A = poisson27(5)
        np.testing.assert_allclose(
            np.asarray(self._csr(A).diagonal()), np.asarray(A.diagonal())
        )


class TestRegistryHygiene:
    def test_solver_names_unique_sorted(self):
        names = repro.solver_names()
        assert list(names) == sorted(set(names))
        assert {"pcg", "pipecg", "h1", "h2", "h3", "pipecg_distributed"} <= set(names)

    def test_register_solver_overwrite_guard(self):
        fn = lambda A, b, **kw: None  # noqa: E731
        repro.register_solver("_plan_test_dummy", fn)
        with pytest.raises(ValueError, match="already registered"):
            repro.register_solver("_plan_test_dummy", fn)
        repro.register_solver("_plan_test_dummy", fn, overwrite=True)

    def test_register_spmv_overwrite_guard(self):
        class _PlanTestMat(DIAMatrix):
            pass

        fn = lambda A, x: x  # noqa: E731
        register_spmv(_PlanTestMat, "custom", fn)
        with pytest.raises(ValueError, match="already registered"):
            register_spmv(_PlanTestMat, "custom", fn)
        register_spmv(_PlanTestMat, "custom", fn, overwrite=True)

    def test_register_reducer_overwrite_guard(self):
        from repro.core.reduce import register_reducer

        factory = lambda axis: (lambda g, d, nn: (g, d, nn))  # noqa: E731
        register_reducer("_plan_test_red", factory)
        with pytest.raises(ValueError, match="already registered"):
            register_reducer("_plan_test_red", factory)
        register_reducer("_plan_test_red", factory, overwrite=True)

    def test_register_dist_method_overwrite_guard(self):
        from repro.core.distributed import DistMethod, register_method

        m = DistMethod(reduce="packed", spmv="halo", equal_shards_only=False)
        register_method("_plan_test_h", m)
        with pytest.raises(ValueError, match="already registered"):
            register_method("_plan_test_h", m)
        register_method("_plan_test_h", m, overwrite=True)


class TestServeEngineCoalescing:
    def test_max_batch_buckets_match_unbatched(self):
        from repro.serve.engine import SolverEngine

        A = poisson27(6)
        _, b = _system(A)
        eng = SolverEngine(A, method="pipecg", atol=1e-6, maxiter=300, max_batch=3)
        B = jnp.stack([(1.0 + 0.5 * k) * b for k in range(7)])  # 3 + 3 + padded 1
        rb = eng.solve_batch(B)
        assert rb.x.shape == B.shape
        for k in range(7):
            assert bool(rb.converged[k])
            np.testing.assert_allclose(
                np.asarray(rb.x[k]), np.asarray(eng.solve(B[k]).x), atol=1e-6
            )
        # all buckets (including the padded remainder) reuse ONE batched trace
        assert eng.plan.trace_count == 2  # 1 single-rhs program + 1 bucket program

    def test_empty_batch_is_a_noop(self):
        from repro.serve.engine import SolverEngine

        A = poisson27(5)
        eng = SolverEngine(A, method="pipecg", maxiter=100, max_batch=3)
        assert eng.solve_batch(jnp.zeros((0, A.n))).x.shape == (0, A.n)
