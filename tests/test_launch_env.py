"""``repro.launch.env`` — pre-jax environment hygiene.

The contract: ``apply_env`` sets the SNIPPETS run.sh environment
(allocator thresholds, log level, XLA device-count flag, x64 policy)
with **setdefault semantics** — an operator's explicit environment
always wins — and is import-order safe: importing ``repro``,
``repro.launch`` or ``repro.launch.env`` must not import jax (the lazy
package layout exists for exactly this), while calling ``apply_env``
*after* jax was imported warns and changes nothing rather than lying.
The subprocess test proves the full sequence end-to-end: import env
module jax-free, apply, then import jax and observe the virtual device
count the flag requested.
"""
import os
import subprocess
import sys

import pytest

from repro.launch.env import DEFAULT_ENV, apply_env, tcmalloc_note


class TestApplyEnv:
    def test_defaults_set_when_absent(self):
        env = {}
        applied = apply_env(env=env)
        assert env == DEFAULT_ENV == applied

    def test_existing_vars_win(self):
        env = {k: "operator-set" for k in DEFAULT_ENV}
        applied = apply_env(env=env)
        assert applied == {}
        assert all(v == "operator-set" for v in env.values())

    def test_devices_and_x64(self):
        env = {}
        applied = apply_env(devices=8, x64=True, env=env)
        assert env["XLA_FLAGS"] == "--xla_force_host_platform_device_count=8"
        assert env["JAX_ENABLE_X64"] == "1"
        assert applied["XLA_FLAGS"] == env["XLA_FLAGS"]

    def test_xla_flags_merged_not_duplicated(self):
        env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
        apply_env(devices=8, extra_xla_flags=("--xla_cpu_foo=1",), env=env)
        # the operator's device count stands; the new flag is appended
        assert env["XLA_FLAGS"] == (
            "--xla_force_host_platform_device_count=4 --xla_cpu_foo=1"
        )
        apply_env(extra_xla_flags=("--xla_cpu_foo=2",), env=env)
        assert env["XLA_FLAGS"].count("--xla_cpu_foo") == 1

    def test_after_jax_import_warns_and_noops(self):
        # this test process imported jax long ago (conftest does)
        assert "jax" in sys.modules
        before = dict(os.environ)
        with pytest.warns(UserWarning, match="after jax was imported"):
            applied = apply_env(devices=2)
        assert applied == {}
        assert dict(os.environ) == before

    def test_tcmalloc_note_respects_existing_preload(self):
        assert tcmalloc_note({"LD_PRELOAD": "/x/libwhatever.so"}) is None
        note = tcmalloc_note({})
        if note is not None:  # only when a system tcmalloc exists
            assert "LD_PRELOAD" in note


class TestImportOrder:
    def test_env_module_imports_jax_free_then_flag_takes_effect(self):
        """The full launcher sequence in a clean interpreter."""
        code = (
            "import sys\n"
            "import repro.launch.env as env\n"
            "import repro, repro.launch\n"
            "assert 'jax' not in sys.modules, 'lazy package pulled jax'\n"
            "applied = env.apply_env(devices=3)\n"
            "assert 'XLA_FLAGS' in applied, applied\n"
            "import jax\n"
            "assert jax.device_count() == 3, jax.device_count()\n"
            "print('OK')\n"
        )
        clean = dict(os.environ)
        for k in ("XLA_FLAGS", "JAX_ENABLE_X64", *DEFAULT_ENV):
            clean.pop(k, None)
        clean["PYTHONPATH"] = os.path.join(
            os.path.dirname(__file__), "..", "src"
        )
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=300, env=clean,
        )
        assert proc.returncode == 0, proc.stderr
        assert "OK" in proc.stdout
