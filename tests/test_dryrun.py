"""Dry-run smoke: one cheap cell end-to-end in a 512-device subprocess.

The full 40-cell x 2-mesh sweep runs via ``python -m repro.launch.dryrun``
(results in experiments/dryrun); here we verify the machinery itself —
lower + compile + roofline extraction on the smallest architecture.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.filterwarnings("ignore")
def test_dryrun_whisper_single_pod(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # dryrun.py sets its own, before importing jax
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-tiny", "--shape", "train_4k",
         "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.load(open(tmp_path / "whisper-tiny_train_4k_single.json"))
    assert rec["status"] == "ok"
    assert rec["program"] == "train_step"
    assert rec["mesh"] == "16x16"
    for k in ("compute_s", "memory_s", "collective_s", "dominant"):
        assert k in rec["roofline_hlo"]
    assert rec["hlo"]["flops_per_chip"] > 0
    assert rec["memory"]["peak_bytes_per_device"] >= 0
    # whisper's vocab (51865) cannot shard 16 ways -> must be logged
    assert any(f["axis"] == "vocab" for f in rec["sharding_fallbacks"])


def test_long500k_skip_reason():
    """Full-attention archs must skip long_500k with an explanatory record,
    without touching any jax device state (logic-only path)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = SRC
    code = (
        "from repro.launch.dryrun import run_cell;"
        "r = run_cell('qwen3-8b', 'long_500k', False, verbose=False);"
        "assert r['status'] == 'skipped', r;"
        "assert 'quadratic' in r['reason'];"
        "print('OK')"
    )
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, timeout=300, env=env)
    assert proc.returncode == 0, proc.stderr[-1500:]
    assert "OK" in proc.stdout
