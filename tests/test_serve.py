"""The async serving tier (``repro.serve``): queue, router, warm start, server.

What these tests pin down:

* **bucket close policy** — full (``max_batch`` reached) vs timeout
  (``max_wait`` after the FIRST request), each with its own counter;
* **backpressure** — a full queue raises ``QueueFull`` at ``put`` and
  counts the rejection; deadlines fail fast with ``DeadlineExceeded``;
* **router** — decade tolerance bucketing, content-keyed pool routing
  (miss -> async build -> hit on one entry), LRU eviction that skips
  pinned entries, build errors published to waiters;
* **warm start** — the manifest round-trip contract: a rebuilt plan's
  ``describe()`` and pool routing key are identical, and a warmed
  replica's first traffic re-traces NOTHING (``trace_count``);
* **SolverServer** — end-to-end correctness vs direct ``plan.solve``,
  two-program steady state, honest per-request iteration counts, and
  graceful drain with zero dropped requests;
* **CountingOperator** — host-side matvec accounting through the jitted
  plan path;
* **engine bucket metrics** — the un-split path (``max_batch=None``)
  records one k-sized bucket instead of nothing.
"""
import json
import time

import jax.numpy as jnp
import numpy as np
import pytest

import repro
import repro.obs as obs
from repro.serve import (
    DeadlineExceeded,
    PlanPool,
    QueueFull,
    RequestQueue,
    ServerClosed,
    SolveRequest,
    SolverServer,
    load_manifest,
    pool_key,
    save_manifest,
    tolerance_bucket,
)
from repro.sparse import CountingOperator, poisson27, spmv


@pytest.fixture(autouse=True)
def _obs_clean():
    obs.disable()
    obs.reset_metrics()
    yield
    obs.disable()
    obs.reset_metrics()


def _system(grid=5):
    A = poisson27(grid)
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
    b = spmv(A, xstar)
    return A, xstar, b


def _req(atol=1e-5, **kw):
    return SolveRequest(b=None, atol=atol, **kw)


# ---------------------------------------------------------------------------
# queue: bucket close policy + backpressure
# ---------------------------------------------------------------------------

class TestRequestQueue:
    def test_bucket_closes_on_full(self):
        obs.enable()
        q = RequestQueue(max_depth=16)
        for _ in range(5):
            q.put(_req())
        batch = q.next_batch(max_batch=4, max_wait=60.0)
        assert len(batch) == 4  # closed by size, long before the timeout
        snap = obs.snapshot()
        assert snap["serve.queue.closed_full"]["value"] == 1.0
        assert "serve.queue.closed_timeout" not in snap

    def test_bucket_closes_on_timeout(self):
        obs.enable()
        q = RequestQueue(max_depth=16)
        q.put(_req())
        q.put(_req())
        t0 = time.monotonic()
        batch = q.next_batch(max_batch=8, max_wait=0.05)
        waited = time.monotonic() - t0
        assert len(batch) == 2  # partial bucket: the timeout edge closed it
        assert waited < 5.0  # not the full-bucket wait
        snap = obs.snapshot()
        assert snap["serve.queue.closed_timeout"]["value"] == 1.0
        assert "serve.queue.closed_full" not in snap

    def test_timeout_counts_from_first_request(self):
        # the clock starts at the FIRST request: a straggler arriving just
        # before t_close joins the bucket but does not extend the wait
        q = RequestQueue(max_depth=16)
        q.put(_req())
        t0 = time.monotonic()
        batch = q.next_batch(max_batch=8, max_wait=0.10)
        assert time.monotonic() - t0 < 1.0
        assert len(batch) == 1

    def test_backpressure_queue_full(self):
        obs.enable()
        q = RequestQueue(max_depth=2)
        q.put(_req())
        q.put(_req())
        with pytest.raises(QueueFull):
            q.put(_req())
        assert obs.snapshot()["serve.rejects.queue_full"]["value"] == 1.0
        assert len(q) == 2  # the rejected request was never admitted

    def test_closed_rejects_but_drains(self):
        obs.enable()
        q = RequestQueue(max_depth=8)
        for _ in range(3):
            q.put(_req())
        q.close()
        with pytest.raises(ServerClosed):
            q.put(_req())
        assert obs.snapshot()["serve.rejects.shutdown"]["value"] == 1.0
        # everything admitted before close still drains...
        assert len(q.next_batch(max_batch=8, max_wait=0.01)) == 3
        # ...and only then does the queue report end-of-stream
        assert q.next_batch(max_batch=8, max_wait=0.01) is None

    def test_expired_deadline_fails_fast(self):
        obs.enable()
        q = RequestQueue(max_depth=8)
        dead = _req(deadline=time.monotonic() - 0.01)
        live = _req(deadline=time.monotonic() + 60.0)
        q.put(dead)
        q.put(live)
        batch = q.next_batch(max_batch=2, max_wait=0.01)
        assert batch == [live]
        with pytest.raises(DeadlineExceeded):
            dead.future.result(timeout=1.0)
        assert obs.snapshot()["serve.rejects.deadline"]["value"] == 1.0

    def test_fail_all(self):
        q = RequestQueue(max_depth=8)
        reqs = [_req() for _ in range(3)]
        for r in reqs:
            q.put(r)
        boom = RuntimeError("plan build failed")
        assert q.fail_all(boom) == 3
        for r in reqs:
            with pytest.raises(RuntimeError, match="plan build failed"):
                r.future.result(timeout=1.0)


# ---------------------------------------------------------------------------
# router: tolerance buckets, pool keys, async builds, eviction
# ---------------------------------------------------------------------------

class TestRouter:
    def test_tolerance_bucket_decades(self):
        assert tolerance_bucket(3e-6) == pytest.approx(1e-6)
        assert tolerance_bucket(9.9e-5) == pytest.approx(1e-5)
        assert tolerance_bucket(1e-5) == pytest.approx(1e-5)
        assert tolerance_bucket(0.0) == 0.0
        assert tolerance_bucket(None) == 0.0

    def test_pool_key_shares_decade_and_splits_method(self):
        cfg = dict(method="pipecg", engine="jnp", M="jacobi",
                   atol=3e-6, rtol=0.0, maxiter=100)
        k1 = pool_key("fp", cfg)
        k2 = pool_key("fp", {**cfg, "atol": 8e-6})       # same decade
        k3 = pool_key("fp", {**cfg, "atol": 3e-5})       # different decade
        k4 = pool_key("fp", {**cfg, "method": "pcg"})
        assert k1 == k2
        assert k1 != k3 and k1 != k4

    def test_miss_builds_async_then_hits(self):
        obs.enable()
        A, _, b = _system(4)
        pool = PlanPool(max_plans=4)
        cfg = dict(method="pipecg", engine="jnp", M="jacobi",
                   atol=1e-5, rtol=0.0, maxiter=100)
        entry, created = pool.get_or_create(A, cfg)
        assert created  # miss: the build is now running on a daemon thread
        again, created2 = pool.get_or_create(A, cfg)
        assert again is entry and not created2  # hit lands on the SAME entry
        plan = entry.wait(timeout=120.0)
        res = plan.solve(b)
        assert bool(res.converged)
        snap = obs.snapshot()
        assert snap["serve.router.misses"]["value"] == 1.0
        assert snap["serve.router.hits"]["value"] == 1.0

    def test_build_error_published(self):
        A, _, _ = _system(4)
        pool = PlanPool(max_plans=4)
        entry, _ = pool.get_or_create(
            A, dict(method="no-such-method", engine="jnp", M="jacobi",
                    atol=1e-5, rtol=0.0, maxiter=50))
        with pytest.raises(Exception):
            entry.wait(timeout=120.0)
        assert entry.error is not None

    def test_lru_eviction_skips_pinned(self):
        A, _, _ = _system(4)
        pool = PlanPool(max_plans=2)
        cfg = dict(method="pipecg", engine="jnp", M="jacobi",
                   rtol=0.0, maxiter=100)
        e1, _ = pool.get_or_create(A, {**cfg, "atol": 1e-4})
        e2, _ = pool.get_or_create(A, {**cfg, "atol": 1e-5})
        e1.wait(timeout=120.0)
        e2.wait(timeout=120.0)
        with e1.pinned():  # e1 is LRU but in-flight: e2 must go instead
            e3, _ = pool.get_or_create(A, {**cfg, "atol": 1e-6})
            keys = [e.key for e in pool.entries()]
            assert e1.key in keys and e3.key in keys
            assert e2.key not in keys

    def test_fingerprint_content_based(self):
        from repro.plan import operator_fingerprint

        A1 = poisson27(4)
        A2 = poisson27(4)       # distinct object, identical content
        A3 = poisson27(5)
        assert A1 is not A2
        assert operator_fingerprint(A1) == operator_fingerprint(A2)
        assert operator_fingerprint(A1) != operator_fingerprint(A3)


# ---------------------------------------------------------------------------
# warm start: the manifest round-trip contract
# ---------------------------------------------------------------------------

class TestWarmStart:
    def test_roundtrip_describe_and_key_identical(self, tmp_path):
        A, _, b = _system(4)
        p = repro.plan(A, method="pipecg", engine="jnp", M="jacobi",
                       atol=1e-5, maxiter=100)
        p.solve(b)
        path = str(tmp_path / "plans.json")
        manifest = save_manifest(path, [p], serve={"max_batch": 3})
        assert manifest["plans"][0]["fingerprint"] == \
            PlanPool().fingerprint(A)

        loaded, serve_cfg = load_manifest(path, warm=True)
        assert serve_cfg == {"max_batch": 3}
        (p2, entry), = loaded
        # identical describe() (sans trace counts)...
        from repro.serve.warmstart import _describe_stable
        assert _describe_stable(p2) == entry["describe"]
        # ...and the identical pool routing key across "processes"
        assert pool_key(entry["fingerprint"], p2.config()) == \
            pool_key(entry["fingerprint"], p.config())

    def test_warm_replica_retraces_nothing(self, tmp_path):
        A, xstar, b = _system(4)
        p = repro.plan(A, method="pipecg", engine="jnp", M="jacobi",
                       atol=1e-5, maxiter=100)
        p.solve(b)
        path = str(tmp_path / "plans.json")
        save_manifest(path, [p], serve={"max_batch": 3})

        loaded, _ = load_manifest(path, warm=True, max_batch=3)
        (p2, _), = loaded
        warmed = p2.trace_count
        assert warmed == 2  # single + bucket program, traced at load
        res = p2.solve(b)                            # first "real" traffic
        resb = p2.solve_batched(jnp.stack([b, 2.0 * b, -b]))
        assert p2.trace_count == warmed  # ZERO new traces
        assert bool(res.converged) and np.asarray(resb.converged).all()
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(xstar),
                                   rtol=1e-3, atol=1e-4)

    def test_strict_catches_drifted_spec(self, tmp_path):
        A, _, b = _system(4)
        p = repro.plan(A, method="pipecg", engine="jnp", M="jacobi",
                       atol=1e-5, maxiter=100)
        path = str(tmp_path / "plans.json")
        save_manifest(path, [p])
        doc = json.load(open(path))
        doc["plans"][0]["operator"]["params"]["n"] = 999  # corrupt the spec
        json.dump(doc, open(path, "w"))
        with pytest.raises(ValueError, match="fingerprint"):
            load_manifest(path, warm=False, strict=True)

    def test_server_from_manifest(self, tmp_path):
        A, _, b = _system(4)
        path = str(tmp_path / "plans.json")
        with SolverServer(max_batch=3, max_wait_ms=2.0, engine="jnp",
                          atol=1e-5, maxiter=100) as srv:
            srv.submit(A, b).result(timeout=300.0)
            srv.save_manifest(path)

        srv2 = SolverServer.from_manifest(path)
        try:
            assert srv2.max_batch == 3  # serve config came along
            plans = srv2.plans()
            assert len(plans) == 1
            before = plans[0].trace_count
            # traffic routes onto the adopted plan (content key!) and
            # re-traces nothing
            futs = srv2.submit_many(A, [b, 2.0 * b, -b],
                                    **plans[0].config())
            for f in futs:
                assert bool(f.result(timeout=300.0).converged)
            assert srv2.plans()[0].trace_count == before
        finally:
            srv2.shutdown(drain=True)


# ---------------------------------------------------------------------------
# the server: end-to-end
# ---------------------------------------------------------------------------

class TestSolverServer:
    def test_correctness_and_two_programs(self):
        A, xstar, b = _system(5)
        with SolverServer(max_batch=3, max_wait_ms=5.0, engine="jnp",
                          atol=1e-5, maxiter=200) as srv:
            # prime the single program deterministically, then burst
            r0 = srv.submit(A, b).result(timeout=300.0)
            futs = srv.submit_many(A, [2.0 * b, -b, 0.5 * b, 3.0 * b])
            results = [f.result(timeout=300.0) for f in futs]
            plans = srv.plans()

        assert len(plans) == 1
        assert plans[0].trace_count == 2  # single + one padded bucket program
        np.testing.assert_allclose(np.asarray(r0.x), np.asarray(xstar),
                                   rtol=1e-3, atol=1e-4)
        direct = repro.plan(A, method="pipecg", engine="jnp", M="jacobi",
                            atol=1e-5, maxiter=200)
        for scale, r in zip([2.0, -1.0, 0.5, 3.0], results):
            assert r.converged
            ref = direct.solve(scale * b)
            np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                                       rtol=1e-4, atol=1e-5)
            # honest per-request iterations: NaN-tail census, not the
            # bucket's shared worst case beyond it
            assert r.iterations == int(ref.iterations)
            assert 0 < r.bucket_occupancy <= 1.0

    def test_graceful_drain_zero_drops(self):
        A, _, b = _system(4)
        srv = SolverServer(max_batch=4, max_wait_ms=2.0, engine="jnp",
                           atol=1e-5, maxiter=100)
        futs = srv.submit_many(A, [(1.0 + 0.25 * i) * b for i in range(11)])
        srv.shutdown(drain=True)  # close admission, serve EVERYTHING queued
        for f in futs:
            assert bool(f.result(timeout=300.0).converged)  # zero dropped
        with pytest.raises(ServerClosed):
            srv.submit(A, b)

    def test_shutdown_without_drain_fails_pending(self):
        A, _, b = _system(4)
        srv = SolverServer(max_batch=4, max_wait_ms=50.0, engine="jnp",
                           atol=1e-5, maxiter=100)
        futs = srv.submit_many(A, [b, 2.0 * b])
        srv.shutdown(drain=False)
        for f in futs:
            with pytest.raises(ServerClosed):
                f.result(timeout=300.0)
            # (the in-flight bucket may still complete; only queued
            # requests are guaranteed to fail — accept either outcome)
            break

    def test_tolerance_decade_shares_plan_tightest_wins(self):
        A, _, b = _system(4)
        with SolverServer(max_batch=2, max_wait_ms=20.0, engine="jnp",
                          maxiter=200) as srv:
            f1 = srv.submit(A, b, atol=9e-6)
            f2 = srv.submit(A, 2.0 * b, atol=2e-6)  # same decade, tighter
            r1, r2 = f1.result(timeout=300.0), f2.result(timeout=300.0)
            assert len(srv.plans()) == 1  # one pooled plan for the decade
        # the bucket ran at the tightest member's atol: 9e-6's residual is
        # at least as small as a direct 9e-6 solve's
        rdirect = repro.plan(A, method="pipecg", engine="jnp", M="jacobi",
                             atol=2e-6, maxiter=200).solve(b)
        assert r1.residual_norm <= float(rdirect.residual_norm) * 1.5 + 1e-12
        assert r1.converged and r2.converged


# ---------------------------------------------------------------------------
# CountingOperator + engine bucket metrics (the satellite fixes)
# ---------------------------------------------------------------------------

class TestCountingOperator:
    def test_counts_through_jitted_plan(self):
        A, _, b = _system(4)
        C = CountingOperator(A)
        p = repro.plan(C, method="pipecg", engine="jnp", M="jacobi",
                       atol=1e-5, maxiter=100)
        res = p.solve(b)
        assert bool(res.converged)
        # every call SITE counted once at trace time: 3 setup + 1 loop
        assert C.trace_calls == 4 and C.calls == 4
        # sites -> per-solve applications: setup once + loop x iterations
        assert C.applications(res) == 3 + int(res.iterations)
        first = C.calls
        p.solve(2.0 * b)  # warm: pinned program, zero new matvec calls
        assert C.calls == first

    def test_eager_counts(self):
        A, _, b = _system(4)
        C = CountingOperator(A)
        y = C.matvec(b)
        np.testing.assert_allclose(np.asarray(y), np.asarray(spmv(A, b)),
                                   rtol=1e-6)
        assert C.calls == 1 and C.trace_calls == 0
        C.reset()
        assert C.calls == 0


class TestEngineBucketMetrics:
    def test_unsplit_path_records_one_bucket(self):
        from repro.serve.engine import SolverEngine

        obs.enable()
        A, _, b = _system(4)
        eng = SolverEngine(A, M="jacobi", method="pipecg", atol=1e-5,
                           maxiter=100, max_batch=None)
        eng.solve_batch(jnp.stack([b, 2.0 * b, -b]))
        snap = obs.snapshot()
        # pre-fix this path recorded NOTHING: now one k-sized bucket
        assert snap["serve.buckets"]["value"] == 1.0
        assert snap["serve.padded_lanes"]["value"] == 0.0
        occ = snap["serve.batch_occupancy"]
        assert occ["count"] == 1 and occ["min"] == 1.0

    def test_bucket_waste_helper(self):
        from repro.serve.engine import bucket_waste

        # two buckets of 2: waste = (5-3) + (7-7) = 2
        assert bucket_waste([3, 5, 7, 7], 2) == 2
        assert bucket_waste([4, 4, 4], 3) == 0
        assert bucket_waste([], 4) == 0
