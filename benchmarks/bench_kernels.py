"""Paper §V-B analogue: kernel fusion effect on the iteration core.

The fusion win is an HBM-traffic property, so besides CPU wall time we
report the traffic model that applies on the TPU target: bytes/element of
the unfused (8 AXPYs + PC + 3 dots as separate passes) vs fused (one pass)
iteration core, extracted from the lowered HLO of both variants with the
same census used for the roofline.

``iteration_cores`` extends this to whole-solver granularity: the three
iteration cores (jnp / pallas / fused_iter) timed per PIPECG iteration on
the same operator, with kernel-launches-per-iteration from the jaxpr
census and achieved bandwidth against the roofline HBM peak. Its results
land in ``BENCH_kernels.json`` when a path is given (the CI smoke step
does), seeding the cross-PR benchmark trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.roofline import HW, analyze_hlo
from repro.kernels import fused_vma_dots, fused_vma_dots_ref
from repro.kernels.common import launches_per_iteration
from repro.obs import structural_bytes_per_elem

from .common import bench_record, emit, seed_key, timeit_call, write_bench_json


def iteration_cores(grid: int = 24, maxiter: int = 20, json_path: str | None = None):
    """Time one PIPECG iteration per core on poisson27(grid^3).

    atol=rtol=0 pins the loop at exactly ``maxiter`` iterations, so
    per-iteration time is wall/maxiter with the (shared) init amortized
    out of the comparison. On CPU the Pallas cores run in interpret mode
    — the launch census and traffic model are the TPU-relevant columns
    there; wall time only orders the cores on TPU itself.
    """
    import repro
    from repro.sparse import poisson27

    A = poisson27(grid)
    b = jnp.sin(jnp.arange(A.n, dtype=jnp.float32))
    backend = jax.default_backend()
    record = bench_record(
        "kernels",
        n=int(A.n),
        n_diags=int(A.data.shape[0]),
        maxiter=int(maxiter),
        backend=backend,
        interpret_kernels=backend != "tpu",
        hbm_peak_gbs=HW["hbm_bw"] / 1e9,
        cores={},
    )
    for core in ("jnp", "pallas", "fused_iter"):
        p = repro.plan(A, method="pipecg", engine=core, M="jacobi",
                       atol=0.0, rtol=0.0, maxiter=maxiter)

        def run(bb, p=p):
            return p._inner(bb, jnp.zeros_like(bb), jnp.float32(0.0), jnp.float32(0.0))

        launches = launches_per_iteration(run, b)
        us = timeit_call(p.solve, b, warmup=1, iters=3)
        us_iter = us / maxiter
        bpe = structural_bytes_per_elem(core, record["n_diags"])
        gbs = record["n"] * bpe / (us_iter * 1e-6) / 1e9
        record["cores"][core] = {
            "us_per_iter": us_iter,
            "launches_per_iter": launches,
            "bytes_per_elem": bpe,
            "achieved_gbs": gbs,
            "frac_of_hbm_peak": gbs / (HW["hbm_bw"] / 1e9),
            "trace_count": p.trace_count,
        }
        emit(
            f"kernels/iteration_cores/{core}",
            us_iter,
            f"N={record['n']};launches_per_iter={launches};"
            f"bytes_per_elem={bpe:.0f};achieved={gbs:.2f}GB/s",
        )
    if json_path:
        write_bench_json(json_path, record)
    return record


# one jit per op = one kernel launch per op, like the paper's unoptimized
# scale/daxpy/ddot cublas call sequence (§V-B Fig. 5). A single jit would
# let XLA fuse everything and hide exactly the effect the paper measures.
_axpy = jax.jit(lambda y, x, a: y + a * x)
_scale_add = jax.jit(lambda y, x, a: x + a * y)
_mul = jax.jit(lambda a, b: a * b)
_dot = jax.jit(lambda a, b: jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32)))


def unfused_calls(z, q, s, p, x, r, u, w, n, m, inv, alpha, beta):
    z = _scale_add(z, n, beta)
    q = _scale_add(q, m, beta)
    s = _scale_add(s, w, beta)
    p = _scale_add(p, u, beta)
    x = _axpy(x, p, alpha)
    r = _axpy(r, s, -alpha)
    u = _axpy(u, q, -alpha)
    w = _axpy(w, z, -alpha)
    m = _mul(inv, w)
    gamma = _dot(r, u)
    delta = _dot(w, u)
    uu = _dot(u, u)
    return z, q, s, p, x, r, u, w, m, jnp.stack([gamma, delta, uu])


def main(n: int = 1 << 20, *, json_path: str | None = None, tiny: bool = False):
    if tiny:
        n = 1 << 16
        iteration_cores(grid=8, maxiter=5, json_path=json_path)
    else:
        iteration_cores(json_path=json_path)
    vecs = [jax.random.normal(seed_key("kernels/vma_core", i), (n,)) for i in range(10)]
    inv = jnp.abs(jax.random.normal(seed_key("kernels/vma_core/inv"), (n,))) + 0.5
    a, b = jnp.float32(0.3), jnp.float32(0.7)

    # the canonical iteration core (core.iteration.pipecg_vma_core) via the
    # kernel oracle, compiled as ONE fused jit
    f_fused_jnp = jax.jit(fused_vma_dots_ref)

    us_u = timeit_call(unfused_calls, *vecs, inv, a, b)
    us_f = timeit_call(f_fused_jnp, *vecs, inv, a, b)
    emit("kernels/vma_core/unfused_calls", us_u, f"N={n};12 separate kernels")
    emit("kernels/vma_core/fused_jnp", us_f, f"N={n};speedup={us_u/us_f:.2f}x")

    # TPU-relevant: HBM traffic of each lowering (bytes per vector element)
    hb_u = 0.0
    hb_u += 4 * analyze_hlo(_scale_add.lower(vecs[0], vecs[8], b).compile().as_text()).hbm_bytes
    hb_u += 4 * analyze_hlo(_axpy.lower(vecs[4], vecs[3], a).compile().as_text()).hbm_bytes
    hb_u += analyze_hlo(_mul.lower(inv, vecs[7]).compile().as_text()).hbm_bytes
    hb_u += 3 * analyze_hlo(_dot.lower(vecs[5], vecs[6]).compile().as_text()).hbm_bytes
    hb_f = analyze_hlo(f_fused_jnp.lower(*vecs, inv, a, b).compile().as_text()).hbm_bytes
    emit("kernels/vma_core/unfused_traffic", hb_u / n, f"bytes_per_elem;total={hb_u/1e6:.0f}MB")
    emit(
        "kernels/vma_core/fused_traffic",
        hb_f / n,
        f"bytes_per_elem;total={hb_f/1e6:.0f}MB;reduction={hb_u/hb_f:.2f}x",
    )

    # The jnp "fused" version still re-reads inputs per output on this
    # backend (single-output kLoop fusions) — which is exactly why the
    # Pallas kernel exists: its BlockSpec tiling streams every operand
    # once per grid step BY CONSTRUCTION: 11 reads + 9 writes = 80 B/elem
    # f32, vs ~157 unfused. That 1.96x is the paper's §V-B win on TPU.
    pallas_bytes = (11 + 9) * 4.0
    emit(
        "kernels/vma_core/pallas_traffic",
        pallas_bytes,
        f"bytes_per_elem;structural;reduction={hb_u/n/pallas_bytes:.2f}x",
    )
    # the Pallas kernel itself (interpret mode on CPU: correctness path, not speed)
    outs = fused_vma_dots(*vecs, inv, a, b)
    jax.block_until_ready(outs)
    emit("kernels/vma_core/pallas_interpret_ok", 0.0, "validated in tests/test_kernels.py")


if __name__ == "__main__":
    main()
