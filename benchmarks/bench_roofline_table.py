"""Emit the 40-cell roofline table from the dry-run JSON records.

Reads experiments/dryrun/*.json (produced by ``python -m
repro.launch.dryrun``) and prints one CSV row per cell; also used by
EXPERIMENTS.md generation. If no records exist it emits a pointer row
instead of failing (benchmarks stay runnable standalone)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def rows(dirname: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*_single.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main():
    recs = rows()
    if not recs:
        emit("roofline/none", 0.0, "run: PYTHONPATH=src python -m repro.launch.dryrun")
        return
    for r in recs:
        cell = f"roofline/{r['arch']}/{r['shape']}"
        if r.get("status") == "skipped":
            emit(cell, 0.0, "skipped:" + r.get("reason", "")[:60])
            continue
        if r.get("status") != "ok":
            emit(cell, 0.0, "FAILED")
            continue
        t = r["roofline_hlo"]
        emit(
            cell,
            t["bound_s"] * 1e6,
            f"dom={t['dominant']};compute_ms={t['compute_s']*1e3:.2f};"
            f"mem_ms={t['memory_s']*1e3:.2f};coll_ms={t['collective_s']*1e3:.2f};"
            f"ratio6nd={r.get('model_vs_hlo_flops') or 0:.3f}",
        )


if __name__ == "__main__":
    main()
