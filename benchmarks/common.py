"""Shared benchmark helpers. Every benchmark prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import time

import jax


def timeit_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
