"""Shared benchmark helpers. Every benchmark prints ``name,us_per_call,derived`` CSV.

Sections that support ``--json`` additionally write ``BENCH_<topic>.json``
records through :func:`bench_record`/:func:`write_bench_json`, which stamp
every record with the environment fingerprint (backend, device kind, x64,
JAX version — ``repro.obs.env_fingerprint``) and a schema version, so two
trajectory points are only ever compared when they are comparable
(``tools/bench_gate.py`` enforces this).

Determinism: all synthetic problem data is derived from :func:`seed_key`
— a name-keyed PRNG, not an ambient counter — so re-running a benchmark
reproduces bit-identical inputs and the convergence-iteration columns of
the trajectory are stable across runs and machines.
"""
from __future__ import annotations

import json
import time
import zlib

import jax

BENCH_SCHEMA = 2  # bump when record layout changes incompatibly


def seed_key(name: str, i: int = 0):
    """Deterministic PRNGKey for a named benchmark input.

    Keyed on a stable hash of ``name`` (crc32, not Python's salted
    ``hash``) folded with ``i`` — the same (name, i) yields the same data
    in every process, which is what makes trajectory points comparable.
    """
    return jax.random.fold_in(jax.random.PRNGKey(zlib.crc32(name.encode()) & 0x7FFFFFFF), i)


def timeit_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_record(topic: str, **fields) -> dict:
    """A BENCH_<topic>.json skeleton: topic + schema + env fingerprint."""
    from repro.obs import env_fingerprint

    rec = {"bench": topic, "schema": BENCH_SCHEMA, "env": env_fingerprint()}
    rec.update(fields)
    return rec


def write_bench_json(path: str, record: dict) -> None:
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    emit(f"{record.get('bench', 'bench')}/json", 0.0, path)
