"""Serving-tier benchmark: queue wait, bucket occupancy, program count.

Drives a :class:`repro.serve.SolverServer` (the async tier from
docs/serving.md) with the same mixed-size workload shape as
``repro.launch.serve``: a waited-on priming single (so the single-rhs
program traces deterministically) followed by bursts cycling bucket
sizes 1 / cap / cap//2 / 3. Emits the serving SLOs:

* ``queue_wait_p50_us`` / ``queue_wait_p95_us`` — admission-to-launch
  latency (timing-gated, env-fingerprinted, 4x band);
* ``programs_compiled`` — total XLA programs traced across the pool;
  structural (any increase over the committed trajectory fails CI — a
  third program per plan means the two-program steady state regressed);
* ``occupancy_mean`` — bucket-shape quality (informational: bucket
  formation is timing-dependent, so it is recorded but not gated);
* ``iters_min`` / ``iters_max`` — per-request honest iteration counts
  from the NaN-tail census (convergence-gated).

Inputs are deterministic (fixed rhs scalings of one spmv-made b), so the
structural and convergence columns are stable across runs.
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp

from repro.serve import SolverServer
from repro.sparse import poisson27, spmv

from .common import bench_record, emit, write_bench_json

MAX_BATCH = 4
REQUESTS = 24


def _workload(server: SolverServer, A, requests: int):
    """Prime both programs, then burst mixed bucket sizes; returns the
    burst results only — queue waits should reflect the warm steady
    state, not the one-time compiles (those are the launcher's story)."""
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
    b = spmv(A, xstar)
    server.submit(A, b).result(timeout=300.0)          # single program
    for f in server.submit_many(A, [b] * MAX_BATCH):   # bucket program
        f.result(timeout=300.0)
    t0 = time.perf_counter()
    futures, i = [], 1
    while i < requests:
        for size in (1, MAX_BATCH, max(MAX_BATCH // 2, 1), 3):
            k = min(size, requests - i)
            if k <= 0:
                break
            futures += server.submit_many(
                A, [(1.0 + 0.1 * (i + j)) * b for j in range(k)]
            )
            i += k
    results = [f.result(timeout=300.0) for f in futures]
    return results, time.perf_counter() - t0


def _pct(sorted_xs, q):
    return sorted_xs[min(int(q * (len(sorted_xs) - 1)), len(sorted_xs) - 1)]


def main(tiny: bool = False, json_path: str | None = None):
    dims = [6] if tiny else [6, 10]
    record_mats = {}
    for dim in dims:
        A = poisson27(dim)
        name = f"poisson27-{dim}"
        with SolverServer(max_batch=MAX_BATCH, max_wait_ms=5.0,
                          method="pipecg", engine="auto", atol=1e-5,
                          maxiter=2000) as server:
            results, wall = _workload(server, A, REQUESTS)
            programs = sum(p.trace_count for p in server.plans())

        waits = sorted(r.queue_wait_s * 1e6 for r in results)
        occ = [r.bucket_occupancy for r in results]
        iters = [r.iterations for r in results]
        p50, p95 = _pct(waits, 0.5), _pct(waits, 0.95)
        emit(f"serve/{name}/queue_wait_p50", p50, f"p95={p95:.0f}us")
        emit(f"serve/{name}/request", wall * 1e6 / len(results),
             f"occ={sum(occ) / len(occ):.2f},programs={programs}")
        record_mats[name] = {
            "n": A.n,
            "requests": len(results),
            "queue_wait_p50_us": p50,
            "queue_wait_p95_us": p95,
            "occupancy_mean": sum(occ) / len(occ),
            "programs_compiled": programs,
            "iters_min": int(min(iters)),
            "iters_max": int(max(iters)),
        }

    if json_path:
        write_bench_json(json_path, bench_record(
            "serve", tiny=tiny, max_batch=MAX_BATCH, matrices=record_mats,
        ))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    main(tiny=args.tiny, json_path=args.json)
