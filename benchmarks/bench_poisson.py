"""Paper Fig. 8 analogue: 125-pt Poisson problems + the Hybrid-3 machinery.

The paper's out-of-GPU-memory scenario maps to "operator larger than one
chip's slice": we report (a) PIPECG vs PCG on 125-pt Poisson operators,
(b) the performance-model decomposition quality (nnz balance across 8
parts, uniform and skewed weights), which is what drives Hybrid-3's
overlap, and (c) solve-to-convergence wall time per iteration.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import jacobi, pcg, pipecg
from repro.core.perfmodel import decompose
from repro.sparse import poisson125, spmv

from .common import emit, timeit_call


def main(sizes=(12, 16)):
    for n in sizes:
        A = poisson125(n)
        xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
        b = spmv(A, xstar)
        M = jacobi(A)
        it = 30
        us_pcg = timeit_call(lambda: pcg(A, b, M=M, atol=0.0, maxiter=it), warmup=1, iters=3)
        us_pipe = timeit_call(lambda: pipecg(A, b, M=M, atol=0.0, maxiter=it), warmup=1, iters=3)
        emit(f"poisson125/n{n}/pcg", us_pcg / it, f"N={A.n};nnz/N={A.nnz()/A.n:.1f}")
        emit(f"poisson125/n{n}/pipecg", us_pipe / it, f"speedup={us_pcg/us_pipe:.2f}x")

        # performance-model decomposition quality (the Hybrid-3 enabler)
        for wname, w in (("uniform", None), ("skew2x", np.array([2.0] + [1.0] * 7))):
            bounds = decompose(A, 8, weights=w)
            data = np.asarray(A.data)
            row_nnz = (data != 0).sum(axis=0)
            shares = np.array([row_nnz[bounds[i]: bounds[i + 1]].sum() for i in range(8)], float)
            target = (w / w.sum() if w is not None else np.full(8, 1 / 8))
            err = float(np.abs(shares / shares.sum() - target).max())
            emit(f"poisson125/n{n}/decomp_{wname}", err * 100, "max_nnz_share_err_pct")


if __name__ == "__main__":
    main()
