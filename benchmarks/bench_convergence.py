"""Convergence-equivalence table (the paper's implicit Table: all methods
run to the same tolerance). Reports iterations-to-1e-5 per method per
matrix, the trimmed convergence-curve endpoints (``repro.obs
.convergence_curve`` — the NaN-padded history sliced to the real curve)
and the residual-replacement robustness margin."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import chronopoulos_cg, jacobi, pcg, pipecg
from repro.obs import convergence_curve
from repro.sparse import poisson27, spmv, table1_matrix

from .common import emit


def main():
    mats = [
        ("bcsstk15", table1_matrix("bcsstk15")),
        ("gyro", table1_matrix("gyro")),
        ("poisson27-16", poisson27(16)),
    ]
    for name, A in mats:
        xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
        b = spmv(A, xstar)
        M = jacobi(A)
        rows = {
            "pcg": pcg(A, b, M=M, atol=1e-5, maxiter=4000),
            "chrono": chronopoulos_cg(A, b, M=M, atol=1e-5, maxiter=4000),
            "pipecg": pipecg(A, b, M=M, atol=1e-5, maxiter=4000),
            "pipecg-rr50": pipecg(A, b, M=M, atol=1e-5, maxiter=4000, replace_every=50),
        }
        for meth, res in rows.items():
            true_res = float(jnp.linalg.norm(b - spmv(A, res.x)))
            curve = convergence_curve(res)  # len(curve) == iterations + 1
            emit(
                f"convergence/{name}/{meth}",
                float(res.iterations),
                f"iters;true_res={true_res:.2e};converged={bool(res.converged)};"
                f"curve={curve[0]:.1e}->{curve[-1]:.1e}({len(curve)}pts)",
            )


if __name__ == "__main__":
    main()
