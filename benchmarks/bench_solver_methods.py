"""Paper Fig. 6 / Fig. 7 analogue: solver-method comparison per matrix.

Matrices: synthetic analogues of the paper's SuiteSparse Table I (matched
N and nnz/N; big ones scaled to CPU size) + a 27-pt Poisson. Methods are
rows of the ``repro.solve`` registry: PCG (the paper's Paralution/PETSc
baseline algorithm), Chronopoulos-Gear, PIPECG (Alg. 2), and PIPECG with
the fused Pallas iteration core.

Reported: time per solver ITERATION (us) — the paper's speedups are
iteration-cost driven since all variants converge in the same #iterations
(verified in `derived`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import solve
from repro.sparse import poisson27, spmv, table1_matrix

from .common import emit, timeit_call

MATRICES = [
    ("bcsstk15", lambda: table1_matrix("bcsstk15", scale=1.0)),       # N=3948
    ("gyro", lambda: table1_matrix("gyro", scale=1.0)),               # N=17361
    ("boneS01@10%", lambda: table1_matrix("boneS01", scale=0.1)),     # N~12.7k
    ("offshore@10%", lambda: table1_matrix("offshore", scale=0.1)),   # N~26k
    ("poisson27-20", lambda: poisson27(20)),                          # N=8000
]

# (method, engine) rows of the repro.solve registry
METHODS = {
    "pcg": ("pcg", "jnp"),
    "chrono": ("chronopoulos", "jnp"),
    "pipecg": ("pipecg", "jnp"),
    "pipecg-fused": ("pipecg", "pallas"),
}


def main(iters_per_solve: int = 40):
    for mname, gen in MATRICES:
        A = gen()
        xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
        b = spmv(A, xstar)
        # convergence equivalence (the paper's correctness premise)
        its = {
            k: int(solve(A, b, method=k, M="jacobi", atol=1e-5, maxiter=2000).iterations)
            for k in ("pcg", "pipecg")
        }
        for meth, (method, engine) in METHODS.items():
            us = timeit_call(
                lambda: solve(
                    A, b, method=method, engine=engine, M="jacobi",
                    atol=0.0, maxiter=iters_per_solve,
                ),
                warmup=1,
                iters=3,
            )
            emit(
                f"solver/{mname}/{meth}",
                us / iters_per_solve,
                f"N={A.n};nnz/N={A.nnz()/A.n:.1f};iters_pcg={its['pcg']};iters_pipecg={its['pipecg']}",
            )


if __name__ == "__main__":
    main()
