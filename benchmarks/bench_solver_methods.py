"""Paper Fig. 6 / Fig. 7 analogue: solver-method comparison per matrix.

Matrices: synthetic analogues of the paper's SuiteSparse Table I (matched
N and nnz/N; big ones scaled to CPU size) + a 27-pt Poisson. Methods are
rows of the solver registry, executed through the plan/execute API: one
``repro.plan`` per (matrix, method) pins the compiled loop outside the
timed region — the timer sees pure iteration cost, exactly the quantity
the paper's speedups are made of (all variants converge in the same
#iterations, verified in `derived`).

``--tiny`` runs a seconds-scale subset through the same plan path — the
CI smoke mode that keeps the serving workflow exercised on every push —
and ``json_path`` writes ``BENCH_solver_methods.json``: per matrix×method
us/iter, kernel launches/iter (jaxpr census), the structural GB/s model
and the convergence-equivalence iteration counts, all stamped with the
environment fingerprint so ``tools/bench_gate.py`` can tell which columns
are comparable across trajectory points.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

import repro
from repro.obs import plan_launches_per_iteration, structural_bytes_per_elem
from repro.sparse import poisson27, spmv, table1_matrix

from .common import bench_record, emit, timeit_call, write_bench_json

MATRICES = [
    ("bcsstk15", lambda: table1_matrix("bcsstk15", scale=1.0)),       # N=3948
    ("gyro", lambda: table1_matrix("gyro", scale=1.0)),               # N=17361
    ("boneS01@10%", lambda: table1_matrix("boneS01", scale=0.1)),     # N~12.7k
    ("offshore@10%", lambda: table1_matrix("offshore", scale=0.1)),   # N~26k
    ("poisson27-20", lambda: poisson27(20)),                          # N=8000
]

TINY_MATRICES = [
    ("poisson27-6", lambda: poisson27(6)),                            # N=216
]

# (method, engine) rows of the solver registry
METHODS = {
    "pcg": ("pcg", "jnp"),
    "chrono": ("chronopoulos", "jnp"),
    "pipecg": ("pipecg", "jnp"),
    "pipecg-fused": ("pipecg", "pallas"),
}


def main(iters_per_solve: int = 40, tiny: bool = False, json_path: str | None = None):
    matrices = TINY_MATRICES if tiny else MATRICES
    if tiny:
        iters_per_solve = min(iters_per_solve, 10)
    record = bench_record(
        "solver_methods",
        iters_per_solve=int(iters_per_solve),
        tiny=bool(tiny),
        matrices={},
    )
    for mname, gen in matrices:
        A = gen()
        xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)  # deterministic rhs: b = A @ 1/sqrt(n)
        b = spmv(A, xstar)
        # convergence equivalence (the paper's correctness premise)
        its = {
            k: int(repro.solve(A, b, method=k, M="jacobi", atol=1e-5, maxiter=2000).iterations)
            for k in ("pcg", "pipecg")
        }
        n_diags = int(A.data.shape[0])
        mrec = {
            "n": int(A.n),
            "nnz_per_row": float(A.nnz() / A.n),
            "iters_pcg": its["pcg"],
            "iters_pipecg": its["pipecg"],
            "methods": {},
        }
        record["matrices"][mname] = mrec
        for meth, (method, engine) in METHODS.items():
            # plan outside the timed region: the timer sees iteration cost only
            p = repro.plan(A, method=method, engine=engine, M="jacobi",
                           atol=0.0, maxiter=iters_per_solve)
            us = timeit_call(lambda: p.solve(b), warmup=1, iters=3)
            assert p.trace_count == 1, (meth, p.trace_count)  # plan reuse, not re-trace
            us_iter = us / iters_per_solve
            launches = plan_launches_per_iteration(p, b)
            core = p.describe().get("core")
            bpe = structural_bytes_per_elem(core, n_diags) if core else None
            gbs = None if bpe is None else A.n * bpe / (us_iter * 1e-6) / 1e9
            mrec["methods"][meth] = {
                "us_per_iter": us_iter,
                "launches_per_iter": launches,
                "bytes_per_elem": bpe,
                "achieved_gbs": gbs,
            }
            emit(
                f"solver/{mname}/{meth}",
                us_iter,
                f"N={A.n};nnz/N={A.nnz()/A.n:.1f};iters_pcg={its['pcg']};iters_pipecg={its['pipecg']}",
            )
    if json_path:
        write_bench_json(json_path, record)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=40, help="iterations per timed solve")
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale CI smoke: tiny matrix, few iterations")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write BENCH_solver_methods.json record")
    args = ap.parse_args()
    main(iters_per_solve=args.iters, tiny=args.tiny, json_path=args.json)
