"""Communication schedules of the distributed methods — h1..h4, pl2, pl3.

The paper's Figures 6-8 compare methods by wall time on a CPU+GPU node; on
the TPU target the distinguishing quantity is the per-iteration collective
schedule, measured exactly from the while-body jaxpr of each method's
shard_map program:

  h1 : 3 separate scalar psums + full-vector all-gather   (most latency)
  h2 : 1 packed psum + full-vector all-gather             (paper's 3N->N)
  h3 : 1 packed psum + 2x bandwidth-wide halo ppermute    (paper's 2-D)
  h4 : 2-stage hierarchical psum (intra-pod + inter-pod)  (2-D mesh)
  pl2: ONE Gram psum per 2 iterations  (depth-2 pipeline)
  pl3: ONE Gram psum per 3 iterations  (depth-3 pipeline)

Emits one CSV row per method and (via ``run.py --json-dir``) a
``BENCH_overlap.json`` record whose ``reductions_per_iter`` /
``ppermutes_per_iter`` / ``allgathers_per_iter`` leaves are gated as
STRUCTURAL by tools/bench_gate.py (any increase fails CI) and whose
``iterations`` leaves get the convergence band — the pl2/pl3
within-10%-of-pipecg acceptance criterion, enforced against the
committed trajectory. ``time_per_iter_us`` rides the timing band.

Runs in a subprocess with 8 virtual devices (the only place a
multi-device mesh exists on this CPU box).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = r"""
import os, time, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import jacobi
from repro.core.distributed import (make_solver_mesh, build_distributed_solver,
                                    get_method)
from repro.kernels.common import while_body_jaxpr, count_primitive
from repro.sparse import balanced_rows, poisson27, shard_dia, shard_vector, spmv

A = poisson27(12)
xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
b = spmv(A, xstar)
M = jacobi(A)
bounds = balanced_rows(A.n, 8)
As = shard_dia(A, bounds)
mesh1 = make_solver_mesh(8)
mesh2 = make_solver_mesh(8, sub=4)
bsh = shard_vector(b, bounds)
ish = shard_vector(M.inv_diag, bounds)

TIMED_ITERS = 64
out = {"devices": 8, "n": A.n, "methods": {}}
for method in ("h1", "h2", "h3", "h4", "pl2", "pl3"):
    mesh = mesh2 if method == "h4" else mesh1
    depth = get_method(method).pipeline_depth
    runner = build_distributed_solver(As, mesh=mesh, method=method,
                                      maxiter=TIMED_ITERS, replace_every=50)
    run = jax.jit(lambda bb, ii, a, r: runner(bb, ii, a, r))

    # structural census on the RR-free program: the steady-state schedule.
    # (residual replacement adds a lax.cond branch whose collectives would
    # be counted statically but execute only every replace_every iters)
    census_runner = build_distributed_solver(As, mesh=mesh, method=method,
                                             maxiter=TIMED_ITERS)
    closed = jax.make_jaxpr(lambda bb, ii, a, r: census_runner(bb, ii, a, r))(
        bsh, ish, jnp.float32(1e-6), jnp.float32(0.0))
    body = while_body_jaxpr(closed.jaxpr)
    red = count_primitive(body, "psum") / depth
    pp = count_primitive(body, "ppermute") / depth
    ag = count_primitive(body, "all_gather") / depth

    # convergence: iterations to atol on the Poisson problem
    res = run(bsh, ish, jnp.float32(1e-6), jnp.float32(0.0))
    iters = int(jax.block_until_ready(res.iterations))

    # timing: fixed-work solve (atol=0 -> all TIMED_ITERS iterations)
    jax.block_until_ready(run(bsh, ish, jnp.float32(0.0), jnp.float32(0.0)))
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(run(bsh, ish, jnp.float32(0.0), jnp.float32(0.0)))
        times.append(time.perf_counter() - t0)
    us_per_iter = sorted(times)[1] / TIMED_ITERS * 1e6

    out["methods"][method] = {
        "pipeline_depth": depth,
        "reductions_per_iter": red,
        "ppermutes_per_iter": pp,
        "allgathers_per_iter": ag,
        "iterations": iters,
        "time_per_iter_us": round(us_per_iter, 1),
    }
    print(f"overlap/{method},{us_per_iter:.1f},"
          f"red/it={red:g};ppermute/it={pp:g};allgather/it={ag:g};iters={iters}")
print("BENCHJSON:" + json.dumps(out))
"""


def main(json_path: str | None = None):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, env=env, timeout=900)
    if out.returncode != 0:
        print(f"overlap/FAILED,0,{out.stderr[-300:]!r}")
        return
    payload = None
    for line in out.stdout.splitlines():
        if line.startswith("BENCHJSON:"):
            payload = json.loads(line[len("BENCHJSON:"):])
        else:
            sys.stdout.write(line + "\n")
    if json_path and payload is not None:
        from .common import bench_record, write_bench_json

        write_bench_json(json_path, bench_record("overlap", **payload))


if __name__ == "__main__":
    main()
