"""Hybrid-1 vs Hybrid-2 vs Hybrid-3 — the communication-schedule comparison.

The paper's Figures 6-8 compare methods by wall time on a CPU+GPU node; on
the TPU target the distinguishing quantity is the per-iteration collective
schedule, which we measure exactly from the lowered shard_map HLO:

  h1: 3 separate scalar psums + full-vector all-gather   (most latency)
  h2: 1 packed psum + full-vector all-gather             (paper's 3N->N)
  h3: 1 packed psum + 2x bandwidth-wide halo ppermute    (paper's 2-D)

Runs in a subprocess with 8 virtual devices (the only place a multi-device
mesh exists on this CPU box).
"""
from __future__ import annotations

import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.core import jacobi
from repro.core.distributed import make_solver_mesh, pipecg_distributed
from repro.launch.roofline import analyze_hlo
from repro.sparse import balanced_rows, poisson27, shard_dia, shard_vector, spmv

A = poisson27(12)
xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
b = spmv(A, xstar)
M = jacobi(A)
bounds = balanced_rows(A.n, 8)
As = shard_dia(A, bounds)
mesh = make_solver_mesh(8)
bsh = shard_vector(b, bounds)
ish = shard_vector(M.inv_diag, bounds)

for method in ("h1", "h2", "h3"):
    fn = partial(pipecg_distributed, mesh=mesh, method=method, atol=1e-6, maxiter=64)
    lowered = jax.jit(lambda a, bb, ii: fn(a, bb, ii)).lower(As, bsh, ish)
    hl = analyze_hlo(lowered.compile().as_text())
    n_coll = {k: v for k, v in hl.coll_by_kind_count.items()}
    per_iter = hl.wire_bytes / 64.0
    print(f"overlap/{method},{per_iter:.1f},counts={n_coll};wire_bytes_64it={hl.wire_bytes:.0f}")
"""


def main():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True, text=True, env=env, timeout=600)
    if out.returncode != 0:
        print(f"overlap/FAILED,0,{out.stderr[-300:]!r}")
        return
    sys.stdout.write(out.stdout)


if __name__ == "__main__":
    main()
