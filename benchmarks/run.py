"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_convergence     — convergence equivalence (correctness premise)
  bench_solver_methods  — Fig. 6/7: method comparison across matrices
  bench_kernels         — §V-B: kernel fusion effect (time + HBM traffic)
  bench_overlap         — h1..h4/pl2/pl3 collective schedules + time/iter
                          (8-dev subprocess; JSON-capable, CI-gated)
  bench_serve           — async serving tier: queue wait p50/p95, bucket
                          occupancy, programs compiled (JSON, CI-gated)
  bench_poisson         — Fig. 8: 125-pt Poisson + perf-model decomposition
  bench_roofline_table  — the 40-cell dry-run roofline (reads experiments/)

CLI (ReFrame-style harness):
  --only SECTION        run one section; repeatable (``--only kernels
                        --only solver_methods``); default is all sections
  --tiny                shrink problem sizes (CI smoke)
  --json-dir DIR        sections that support JSON write
                        ``DIR/BENCH_<section>.json`` records — env-
                        fingerprinted, gate-able by tools/bench_gate.py
  --json PATH           legacy single-file form (kernels section only)
  --obs-dump PATH       run with observability on and write the collected
                        spans + metrics snapshot as one JSON artifact

CI runs ``--tiny --json-dir bench_out --only kernels --only
solver_methods --only overlap --obs-dump bench_out/obs_dump.json`` then gates
``bench_out`` against the committed ``benchmarks/trajectory/`` with
``tools/bench_gate.py`` — a "faster" claim that regresses the trajectory
beyond the noise band fails the build.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


def main(argv=None) -> None:
    from . import (
        bench_convergence,
        bench_kernels,
        bench_overlap,
        bench_poisson,
        bench_roofline_table,
        bench_serve,
        bench_solver_methods,
    )

    sections = [
        ("convergence", bench_convergence.main, {}),
        ("solver_methods", bench_solver_methods.main, {"json_path": True, "tiny": True}),
        ("kernels", bench_kernels.main, {"json_path": True, "tiny": True}),
        ("overlap", bench_overlap.main, {"json_path": True}),
        ("serve", bench_serve.main, {"json_path": True, "tiny": True}),
        ("poisson", bench_poisson.main, {}),
        ("roofline_table", bench_roofline_table.main, {}),
    ]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=[s[0] for s in sections], action="append",
                    default=None, help="run a single section (repeatable)")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink problem sizes (CI smoke)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="legacy: single JSON record path (kernels section)")
    ap.add_argument("--json-dir", metavar="DIR", default=None,
                    help="write BENCH_<section>.json per JSON-capable section")
    ap.add_argument("--obs-dump", metavar="PATH", default=None,
                    help="enable observability; dump spans+metrics JSON here")
    args = ap.parse_args(argv)

    if args.obs_dump:
        from repro.obs import clear_spans, enable, reset_metrics

        enable()
        clear_spans()
        reset_metrics()
    if args.json_dir:
        os.makedirs(args.json_dir, exist_ok=True)

    print("name,us_per_call,derived")
    failed = []
    for name, fn, accepts in sections:
        if args.only is not None and name not in args.only:
            continue
        kwargs = {}
        if accepts.get("json_path"):
            if args.json_dir:
                kwargs["json_path"] = os.path.join(args.json_dir, f"BENCH_{name}.json")
            elif args.json and name == "kernels":
                kwargs["json_path"] = args.json
        if accepts.get("tiny") and args.tiny:
            kwargs["tiny"] = True
        try:
            fn(**kwargs)
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"bench/{name}/FAILED,0,", flush=True)

    if args.obs_dump:
        from repro.obs import snapshot, spans_to_dicts

        with open(args.obs_dump, "w") as f:
            json.dump({"metrics": snapshot(), "spans": spans_to_dicts()}, f, indent=2)
        print(f"bench/obs_dump,0.0,{args.obs_dump}", flush=True)

    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
