"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_convergence     — convergence equivalence (correctness premise)
  bench_solver_methods  — Fig. 6/7: method comparison across matrices
  bench_kernels         — §V-B: kernel fusion effect (time + HBM traffic)
  bench_overlap         — h1/h2/h3 collective schedules (8-dev subprocess)
  bench_poisson         — Fig. 8: 125-pt Poisson + perf-model decomposition
  bench_roofline_table  — the 40-cell dry-run roofline (reads experiments/)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        bench_convergence,
        bench_kernels,
        bench_overlap,
        bench_poisson,
        bench_roofline_table,
        bench_solver_methods,
    )

    sections = [
        ("convergence", bench_convergence.main),
        ("solver_methods", bench_solver_methods.main),
        ("kernels", bench_kernels.main),
        ("overlap", bench_overlap.main),
        ("poisson", bench_poisson.main),
        ("roofline_table", bench_roofline_table.main),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in sections:
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"bench/{name}/FAILED,0,", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
