"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_convergence     — convergence equivalence (correctness premise)
  bench_solver_methods  — Fig. 6/7: method comparison across matrices
  bench_kernels         — §V-B: kernel fusion effect (time + HBM traffic)
  bench_overlap         — h1/h2/h3 collective schedules (8-dev subprocess)
  bench_poisson         — Fig. 8: 125-pt Poisson + perf-model decomposition
  bench_roofline_table  — the 40-cell dry-run roofline (reads experiments/)

CLI: ``--only SECTION`` runs one section, ``--tiny`` shrinks problem
sizes for smoke runs, and ``--json PATH`` makes sections that support it
(today: kernels) write a machine-readable record — CI runs
``--only kernels --tiny --json BENCH_kernels.json`` to track the
iteration-core trajectory across PRs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    from . import (
        bench_convergence,
        bench_kernels,
        bench_overlap,
        bench_poisson,
        bench_roofline_table,
        bench_solver_methods,
    )

    sections = [
        ("convergence", bench_convergence.main, {}),
        ("solver_methods", bench_solver_methods.main, {}),
        ("kernels", bench_kernels.main, {"json_path": True, "tiny": True}),
        ("overlap", bench_overlap.main, {}),
        ("poisson", bench_poisson.main, {}),
        ("roofline_table", bench_roofline_table.main, {}),
    ]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=[s[0] for s in sections], default=None,
                    help="run a single section")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink problem sizes (CI smoke)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write a JSON record for sections that support it")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    failed = []
    for name, fn, accepts in sections:
        if args.only is not None and name != args.only:
            continue
        kwargs = {}
        if accepts.get("json_path") and args.json:
            kwargs["json_path"] = args.json
        if accepts.get("tiny") and args.tiny:
            kwargs["tiny"] = True
        try:
            fn(**kwargs)
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"bench/{name}/FAILED,0,", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
