#!/usr/bin/env python
"""Perf-regression gate: compare BENCH_*.json records against a committed
trajectory and fail when a metric regresses beyond its noise band.

Makes every "faster" claim checkable: CI runs ``benchmarks.run --tiny
--json-dir bench_out``, then

    python tools/bench_gate.py --baseline benchmarks/trajectory --current bench_out

Records are compared file-by-file (matching ``BENCH_<topic>.json``
names), flattened to dotted keys (``cores.fused_iter.launches_per_iter``)
so nesting depth never matters to the rules:

* **Structural metrics** (``launches_per_iter``, ``bytes_per_elem``) are
  properties of the program's construction, noise-free by definition:
  any increase over baseline fails. No env check needed — a census does
  not depend on the machine.
* **Convergence metrics** (``iters_*``, ``iterations``) get a small band
  (default 10%): the math should not drift, but atol-edge flakiness on a
  different BLAS is not a regression.
* **Timing metrics** (``us_per_*``, ``*_gbs``, ``*_time_*``) are only
  compared when the two records' env fingerprints are comparable
  (backend, device_kind, x64 — ``repro.obs.comparable_env``); CI shares
  one runner class so they usually are. The default band is wide (4x)
  because ``--tiny`` problems are microseconds-scale and shared/loaded
  runners routinely swing 3-4x (measured: a concurrent test suite on
  this repo's dev box slowed the tiny benches ~4x) — the gate exists to
  catch order-of-magnitude regressions (a fused kernel silently falling
  back to the unfused path), not 5% jitter. Tighten with
  ``--time-band`` on quiet dedicated hardware.
* A key present in baseline but **missing from current** fails: a
  benchmark silently dropping a column is exactly the kind of coverage
  rot a gate exists to catch. Keys new in current are reported, not
  failed (trajectory grows; ``--update`` refreshes the baseline).

Exit status: 0 = within bands, 1 = regression (or missing
baseline/current files), plus a per-key report either way.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys
from typing import Dict, Tuple

# metric classification by key leaf (last dotted component)
STRUCTURAL = ("launches_per_iter", "bytes_per_elem",
              # distributed collective censuses (BENCH_overlap.json): a
              # schedule is a property of program construction, noise-free
              "reductions_per_iter", "ppermutes_per_iter", "allgathers_per_iter",
              # serving tier (BENCH_serve.json): XLA programs traced across
              # the plan pool — a third program per plan means the
              # two-program steady state regressed
              "programs_compiled")
CONVERGENCE_PREFIXES = ("iters_", "iterations")
TIMING_MARKERS = ("us_per_", "_gbs", "time_", "_us")
# provenance/config keys: informational, never gated
SKIP_LEAVES = {"schema", "bench", "backend", "interpret_kernels", "n", "n_diags",
               "maxiter", "iters_per_solve", "tiny", "nnz_per_row", "hbm_peak_gbs",
               "frac_of_hbm_peak", "trace_count"}


def _flatten(obj, prefix: str = "") -> Dict[str, object]:
    out: Dict[str, object] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            key = f"{prefix}.{k}" if prefix else str(k)
            out.update(_flatten(v, key))
    else:
        out[prefix] = obj
    return out


def comparable_env(a: dict, b: dict) -> bool:
    """Mirror of repro.obs.comparable_env — kept importless so the gate
    runs standalone (no PYTHONPATH, no jax) on any CI runner."""
    return all(a.get(k) == b.get(k) for k in ("backend", "device_kind", "x64"))


def classify(key: str) -> str:
    leaf = key.rsplit(".", 1)[-1]
    if leaf in SKIP_LEAVES or key.startswith("env."):
        return "skip"
    if leaf in STRUCTURAL:
        return "structural"
    if any(leaf.startswith(p) or leaf == p for p in CONVERGENCE_PREFIXES):
        return "convergence"
    if any(m in leaf for m in TIMING_MARKERS):
        return "timing"
    return "skip"


def gate_record(base: dict, cur: dict, *, time_band: float, conv_band: float,
                name: str) -> Tuple[list, list]:
    """Returns (failures, notes) as lists of strings."""
    failures, notes = [], []
    fb, fc = _flatten(base), _flatten(cur)
    envs_ok = comparable_env(base.get("env", {}), cur.get("env", {}))
    if not envs_ok:
        notes.append(f"{name}: env fingerprints differ — timing metrics skipped")

    for key, bval in sorted(fb.items()):
        kind = classify(key)
        if kind == "skip":
            continue
        if key not in fc:
            failures.append(f"{name}:{key} present in baseline, MISSING in current")
            continue
        cval = fc[key]
        if bval is None or cval is None:
            if bval is not None and cval is None:
                failures.append(f"{name}:{key} was {bval}, now None")
            continue
        b, c = float(bval), float(cval)
        if kind == "structural":
            if c > b:
                failures.append(
                    f"{name}:{key} structural regression: {b:g} -> {c:g} "
                    "(launches/traffic are noise-free; any increase fails)"
                )
        elif kind == "convergence":
            if c > b * (1.0 + conv_band):
                failures.append(
                    f"{name}:{key} convergence regression: {b:g} -> {c:g} "
                    f"(band {conv_band:.0%})"
                )
        elif kind == "timing":
            if not envs_ok:
                continue
            # "bigger is worse" for times, "smaller is worse" for GB/s
            if "_gbs" in key.rsplit(".", 1)[-1]:
                if c < b / time_band:
                    failures.append(
                        f"{name}:{key} bandwidth regression: {b:.3g} -> {c:.3g} GB/s "
                        f"(band {time_band:g}x)"
                    )
            elif c > b * time_band:
                failures.append(
                    f"{name}:{key} timing regression: {b:.3g} -> {c:.3g} "
                    f"(band {time_band:g}x)"
                )
    for key in sorted(set(fc) - set(fb)):
        if classify(key) != "skip":
            notes.append(f"{name}:{key} new in current (not in baseline)")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--baseline", required=True,
                    help="directory of committed BENCH_*.json trajectory files")
    ap.add_argument("--current", required=True,
                    help="directory of freshly produced BENCH_*.json files")
    ap.add_argument("--time-band", type=float, default=4.0,
                    help="timing noise band as a ratio (default 4x)")
    ap.add_argument("--conv-band", type=float, default=0.10,
                    help="convergence-iterations band as a fraction (default 10%%)")
    ap.add_argument("--update", action="store_true",
                    help="copy current records over the baseline instead of gating")
    args = ap.parse_args(argv)

    base_files = {os.path.basename(p): p
                  for p in glob.glob(os.path.join(args.baseline, "BENCH_*.json"))}
    cur_files = {os.path.basename(p): p
                 for p in glob.glob(os.path.join(args.current, "BENCH_*.json"))}

    if args.update:
        os.makedirs(args.baseline, exist_ok=True)
        for name, path in sorted(cur_files.items()):
            shutil.copy(path, os.path.join(args.baseline, name))
            print(f"bench_gate: baseline updated <- {name}")
        return 0

    if not base_files:
        print(f"bench_gate: no BENCH_*.json under {args.baseline}", file=sys.stderr)
        return 1
    if not cur_files:
        print(f"bench_gate: no BENCH_*.json under {args.current}", file=sys.stderr)
        return 1

    failures, notes = [], []
    for name in sorted(base_files):
        if name not in cur_files:
            failures.append(f"{name}: baseline record has no current counterpart")
            continue
        with open(base_files[name]) as f:
            base = json.load(f)
        with open(cur_files[name]) as f:
            cur = json.load(f)
        fl, nt = gate_record(base, cur, time_band=args.time_band,
                             conv_band=args.conv_band, name=name)
        failures += fl
        notes += nt
    for name in sorted(set(cur_files) - set(base_files)):
        notes.append(f"{name}: new record, no baseline yet (commit it to start gating)")

    for n in notes:
        print(f"bench_gate: note: {n}")
    if failures:
        for f_ in failures:
            print(f"bench_gate: FAIL: {f_}", file=sys.stderr)
        print(f"bench_gate: {len(failures)} regression(s) beyond the noise band",
              file=sys.stderr)
        return 1
    print(f"bench_gate: OK — {len(base_files)} record(s) within bands "
          f"(time {args.time_band:g}x, convergence {args.conv_band:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
