"""Generate the §Dry-run and §Roofline tables for EXPERIMENTS.md from
experiments/dryrun/*.json.

    PYTHONPATH=src python tools/gen_experiments.py > experiments/roofline_tables.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

DRY = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(mesh_tag: str):
    recs = {}
    for p in sorted(glob.glob(os.path.join(DRY, f"*_{mesh_tag}.json"))):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def improvement_note(r):
    t = r["roofline_hlo"]
    dom = t["dominant"]
    arch, shape = r["arch"], r["shape"]
    kinds = r.get("collectives", {}).get("by_kind_bytes", {})
    if dom == "memory":
        return "chunked/flash attention kills the (T,T) f32 score traffic"
    if dom == "collective":
        if "moe" in arch or r.get("analytic", {}).get("params", 0) > 5e9 and "olmoe" in arch:
            return "explicit shard_map MoE dispatch (a2a instead of GSPMD gather fallback)"
        if shape in ("decode_32k", "long_500k"):
            return "seq-sharded KV cache (flash-decode layout) removes cache resharding"
        return "bf16 collectives + save_collectives remat halves AR traffic"
    return "increase per-chip work (larger microbatch) or reduce precision"


def main():
    singles = load("single")
    multis = load("multi")

    print("### Single-pod (16x16 = 256 chips) roofline — all 40 cells\n")
    print("| arch | shape | prog | peak GiB/dev | compute s | memory s | collective s | dominant | MODEL_FLOPS/HLO | what moves the bound |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    order_sh = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    archs = sorted({a for a, _ in singles})
    for a in archs:
        for s in order_sh:
            r = singles.get((a, s))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {a} | {s} | — | — | — | — | — | skipped | — | full attention at 524k: by design (DESIGN.md §4) |")
                continue
            if r["status"] != "ok":
                print(f"| {a} | {s} | — | — | — | — | — | FAILED | — | {r.get('error','')[:60]} |")
                continue
            t = r["roofline_hlo"]
            ratio = r.get("model_vs_hlo_flops") or 0
            print(
                f"| {a} | {s} | {r['program']} | {fmt_bytes(r['memory']['peak_bytes_per_device'])} "
                f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} | {t['collective_s']:.3f} "
                f"| **{t['dominant']}** | {ratio:.2f} | {improvement_note(r)} |"
            )

    print("\n### Multi-pod (2x16x16 = 512 chips) — compile gate\n")
    print("| arch | shape | status | compile s | peak GiB/dev | wire GB/chip |")
    print("|---|---|---|---|---|---|")
    for a in archs:
        for s in order_sh:
            r = multis.get((a, s))
            if r is None:
                continue
            if r["status"] != "ok":
                print(f"| {a} | {s} | {r['status']} | — | — | — |")
                continue
            wb = r["collectives"]["wire_bytes_per_chip"] / 1e9
            print(
                f"| {a} | {s} | ok | {r['compile_s']:.1f} | "
                f"{fmt_bytes(r['memory']['peak_bytes_per_device'])} | {wb:.1f} |"
            )

    n_ok_s = sum(1 for r in singles.values() if r["status"] == "ok")
    n_skip_s = sum(1 for r in singles.values() if r["status"] == "skipped")
    n_ok_m = sum(1 for r in multis.values() if r["status"] == "ok")
    n_skip_m = sum(1 for r in multis.values() if r["status"] == "skipped")
    print(
        f"\nTotals: single-pod {n_ok_s} compiled + {n_skip_s} by-design skips; "
        f"multi-pod {n_ok_m} compiled + {n_skip_m} skips (of 40 cells each)."
    )


if __name__ == "__main__":
    main()
