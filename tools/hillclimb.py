"""Perf-iteration driver (EXPERIMENTS.md §Perf).

Runs a (arch, shape) cell under a sequence of named variants and prints the
three roofline terms for each, so every hypothesis -> change -> before ->
after cycle is one invocation:

    PYTHONPATH=src python tools/hillclimb.py --arch internlm2-1.8b --shape train_4k \
        --variants baseline,flash512,flash512+saveAR

Variant vocabulary (combine with '+'):
    baseline      paper-faithful step as used in the 40-cell sweep
    flashN        chunked online-softmax attention, chunk=N (e.g. flash512)
    saveAR        remat policy save_collectives (keep post-psum activations)
    seqkv         decode cache layout seq_model (flash-decode sharding)
    pipeclip      pipelined (one-step-stale) gradient clip
    moeshard      explicit shard_map MoE dispatch (local experts + one psum)
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_cell  # noqa: E402  (sets XLA_FLAGS first)


def parse_variant(spec: str) -> dict:
    v: dict = {}
    if spec == "baseline":
        return v
    for part in spec.split("+"):
        if part.startswith("flash"):
            v["attn_chunk"] = int(part[len("flash"):])
        elif part == "saveAR":
            v["remat"] = "save_collectives"
        elif part == "seqkv":
            v["cache_layout"] = "seq_model"
        elif part == "pipeclip":
            v["pipelined_clip"] = True
        elif part == "moeshard":
            v["moe_shard_map"] = True
        else:
            raise SystemExit(f"unknown variant token {part!r}")
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    rows = []
    for spec in args.variants.split(","):
        v = parse_variant(spec)
        rec = run_cell(args.arch, args.shape, False, verbose=False, variant=v)
        tag = f"{args.arch}_{args.shape}_{spec}"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=float)
        t = rec["roofline_hlo"]
        rows.append((spec, t))
        print(
            f"{spec:28s} compute={t['compute_s']:8.3f}s memory={t['memory_s']:8.3f}s "
            f"collective={t['collective_s']:8.3f}s dom={t['dominant']:10s} "
            f"bound={t['bound_s']:8.3f}s peak/dev={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB",
            flush=True,
        )
    base = rows[0][1]["bound_s"]
    for spec, t in rows[1:]:
        print(f"{spec}: bound {base:.3f}s -> {t['bound_s']:.3f}s  ({base / t['bound_s']:.2f}x)")


if __name__ == "__main__":
    main()
