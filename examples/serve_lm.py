"""Batched serving demo: prefill a batch of prompts, decode with the
jitted engine, for any of the 10 architectures (reduced size on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b --new-tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs, reduced
from repro.models import build_model
from repro.serve import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_configs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} family={cfg.family} params={api.n_params():,}")

    key = jax.random.PRNGKey(7)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (args.batch, cfg.enc_seq, cfg.d_model), api.dtype)
    if cfg.family == "vlm":
        batch["img_feats"] = jax.random.normal(key, (args.batch, cfg.n_img_tokens, cfg.d_model), api.dtype)

    t0 = time.time()
    out = generate(api, params, batch, ServeConfig(max_new_tokens=args.new_tokens,
                                                   temperature=args.temperature), key=key)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"generated {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s incl. compile)")
    for i in range(min(args.batch, 2)):
        print(f"  seq {i}: ...{out[i, args.prompt_len-4:].tolist()}")


if __name__ == "__main__":
    main()
