"""Distributed PIPECG on a 125-pt Poisson operator — the paper's Hybrid-3.

Runs on 8 virtual devices (the XLA flag below must precede the jax import),
uses the performance model to decompose rows by nnz with a simulated slow
device, and solves with the 2-D (local/halo overlap) schedule. One
``repro.plan`` carries all of the setup — decomposition, mesh, the
``ShardedDIA`` operator handle, the compiled shard_map loop — and then
serves several right-hand sides without repeating any of it.

    PYTHONPATH=src python examples/solve_poisson_distributed.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

import repro
from repro.core.perfmodel import relative_weights
from repro.sparse import partition_stats, poisson125, spmv


def main():
    P = 8
    # N=32768, nnz/N ~ 119 — the paper's Table II class. Shards must stay
    # wider than the 125-pt bandwidth so halos touch ring neighbors only.
    A = poisson125(32)
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
    b = spmv(A, xstar)

    # --- the paper's performance model: one device measured 1.5x slower ---
    step_times = np.array([1.0, 1.0, 1.0, 1.5, 1.0, 1.0, 1.0, 1.0])
    weights = relative_weights(step_times)

    # --- plan once: decomposition + mesh + ShardedDIA handle + compiled loop ---
    p = repro.plan(A, method="h3", M="jacobi", shards=P, weights=weights,
                   atol=1e-5,  # the paper's tolerance; f32 attainable at this N
                   maxiter=1000)
    print("rows per shard:", list(p.describe()["rows_per_shard"]))
    stats = partition_stats(A, np.asarray(p.bounds))
    for i, s in enumerate(stats["shards"]):
        print(f"  shard {i}: rows={s['rows']:4d} nnz_local={s['nnz_local']:6d} nnz_halo={s['nnz_halo']:5d}")

    # --- serve several rhs through the one plan: nothing is re-sharded ---
    res = p.solve(b)
    for scale in (2.0, -1.0, 0.5):
        p.solve(scale * b)
    ref = repro.solve(A, b, method="pipecg", M="jacobi", atol=1e-5, maxiter=1000)
    print(
        f"h3 distributed: iters={int(res.iterations)} (single-device {int(ref.iterations)})  "
        f"|x - x_ref|={float(jnp.linalg.norm(res.x - ref.x)):.2e}  "
        f"true residual={float(jnp.linalg.norm(b - spmv(A, res.x))):.2e}  "
        f"traces after 4 rhs={p.trace_count}"
    )


if __name__ == "__main__":
    main()
