"""Distributed PIPECG on a 125-pt Poisson operator — the paper's Hybrid-3.

Runs on 8 virtual devices (the XLA flag below must precede the jax import),
uses the performance model to decompose rows by nnz with a simulated slow
device, and solves with the 2-D (local/halo overlap) schedule — all
through the ``repro.solve`` registry: ``method="h3"`` is configuration
(packed psum + halo SPMV) of the same shared iteration core the
single-device reference runs.

    PYTHONPATH=src python examples/solve_poisson_distributed.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

from repro import solve
from repro.core.perfmodel import decompose, relative_weights
from repro.sparse import partition_stats, poisson125, spmv


def main():
    P = 8
    # N=32768, nnz/N ~ 119 — the paper's Table II class. Shards must stay
    # wider than the 125-pt bandwidth so halos touch ring neighbors only.
    A = poisson125(32)
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
    b = spmv(A, xstar)

    # --- the paper's performance model: one device measured 1.5x slower ---
    step_times = np.array([1.0, 1.0, 1.0, 1.5, 1.0, 1.0, 1.0, 1.0])
    weights = relative_weights(step_times)
    bounds = decompose(A, P, weights=weights)
    stats = partition_stats(A, bounds)
    print("rows per shard:", np.diff(bounds).tolist())
    for i, s in enumerate(stats["shards"]):
        print(f"  shard {i}: rows={s['rows']:4d} nnz_local={s['nnz_local']:6d} nnz_halo={s['nnz_halo']:5d}")

    res = solve(
        A, b, method="h3", M="jacobi", shards=P, weights=weights,
        atol=1e-5,  # the paper's tolerance; f32 attainable at this N
        maxiter=1000,
    )
    ref = solve(A, b, method="pipecg", M="jacobi", atol=1e-5, maxiter=1000)
    print(
        f"h3 distributed: iters={int(res.iterations)} (single-device {int(ref.iterations)})  "
        f"|x - x_ref|={float(jnp.linalg.norm(res.x - ref.x)):.2e}  "
        f"true residual={float(jnp.linalg.norm(b - spmv(A, res.x))):.2e}"
    )


if __name__ == "__main__":
    main()
