"""End-to-end training driver: a reduced qwen3-family model on synthetic
data with checkpointing, prefetch, fused-metrics train step, and crash-safe
resume. CPU-sized by default (~1M params, 200 steps); scale with flags.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 200
    PYTHONPATH=src python examples/train_tiny_lm.py --resume  # continues
"""
import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data import SyntheticConfig, batch_for_step, prefetch_batches
from repro.models import build_model
from repro.runtime import CheckpointManager
from repro.train import (
    AdamWConfig,
    TrainConfig,
    abstract_train_state,
    init_train_state,
    make_train_step,
    warmup_cosine,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), d_model=args.d_model, n_layers=args.layers, vocab_size=512)
    api = build_model(cfg)
    print(f"arch={cfg.name} (reduced) params={api.n_params():,}")

    tc = TrainConfig(optimizer=AdamWConfig(lr=args.lr, clip_norm=1.0, pipelined_clip=True))
    step_fn = jax.jit(make_train_step(api, tc, lr_schedule=warmup_cosine(args.lr, 20, args.steps)))
    state = init_train_state(api, jax.random.PRNGKey(0))

    mgr = CheckpointManager(args.ckpt_dir, save_every=50, keep=2)
    start = 0
    if args.resume:
        restored, s = mgr.restore_latest(jax.eval_shape(lambda: state))
        if restored is not None:
            state, start = restored, s
            print(f"resumed from step {start}")

    dc = SyntheticConfig(batch=args.batch, seq_len=args.seq, vocab_size=cfg.vocab_size, seed=0)
    t0 = time.time()
    for step, batch in enumerate(
        prefetch_batches(dc, start, args.steps - start, cfg, depth=2,
                         place=lambda b: {k: jnp.asarray(v) for k, v in b.items()}),
        start=start,
    ):
        state, metrics = step_fn(state, batch)
        mgr.maybe_save(step + 1, state)
        if step % 20 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d}  loss={float(metrics['loss']):.4f}  "
                f"gnorm={float(metrics['grad_norm']):.3f}  lr={float(metrics['lr']):.2e}  "
                f"({(time.time()-t0):.1f}s)"
            )
    mgr.maybe_save(args.steps, state, force=True)
    mgr.wait()
    print(f"done in {time.time()-t0:.1f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
