"""Quickstart: plan once, solve many — the plan/execute workflow.

The paper's whole premise is that PIPECG setup (preconditioner, data
decomposition, compiled iteration loop) is paid once while the loop runs
many times. ``repro.plan`` is that split made explicit:

    p = repro.plan(A, method="pipecg", M="jacobi")   # setup, paid once
    p.solve(b)                                        # any number of rhs
    p.solve_batched(B)                                # one vmapped program

``repro.solve`` stays available as the one-shot form (it reuses plans
from a keyed cache under the hood), and matrix-free operators plug into
the same plans via ``FunctionOperator``.

Observability rides along (``repro.obs``): enable it and every solve is
timed, span-annotated and summarized into ``plan.last_report`` — the
convergence curve, launches/iteration and achieved-bandwidth numbers
that make a "faster" claim checkable.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

import repro
from repro.obs import convergence_curve
from repro.sparse import FunctionOperator, poisson27, spmv


def main():
    A = poisson27(16)  # 4096 unknowns, SPD, nnz/N ~ 26
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)  # paper's exact solution 1/sqrt(N)
    b = spmv(A, xstar)
    print(f"A: N={A.n}  nnz/N={A.nnz()/A.n:.1f}  bandwidth={A.bandwidth}")

    # --- plan once ---
    p = repro.plan(A, method="pipecg", M="jacobi", atol=1e-6, maxiter=500)
    desc = p.describe()
    print("plan:", ", ".join(f"{k}={desc[k]}" for k in ("method", "engine", "preconditioner", "n")))

    # --- ...then serve right-hand sides against the pinned program ---
    res = p.solve(b)
    print(
        f"solve:   iters={int(res.iterations):3d}  |x-x*|="
        f"{float(jnp.linalg.norm(res.x - xstar)):.2e}  traces={p.trace_count}"
    )
    # the NaN-padded history, trimmed to the real curve (iters+1 points)
    curve = convergence_curve(res)
    print(f"curve:   {curve[0]:.2e} -> {curve[-1]:.2e} in {len(curve) - 1} steps")
    B = jnp.stack([b, 2.0 * b, -0.5 * b, b + 1e-3])
    batch = p.solve_batched(B)  # ONE vmapped XLA program for all four
    print(
        f"batched: {B.shape[0]} rhs in one program, "
        f"iters={[int(i) for i in batch.iterations]}  traces={p.trace_count}"
    )

    # --- matrix-free: the same plan machinery, no materialized matrix ---
    op = FunctionOperator(fn=lambda v: spmv(A, v), n=A.n, out_dtype=b.dtype,
                          diag=A.diagonal())  # diag enables M="jacobi"
    mf = repro.plan(op, method="pipecg", M="jacobi", atol=1e-6, maxiter=500).solve(b)
    print(f"matrix-free FunctionOperator: iters={int(mf.iterations):3d}  "
          f"|x-x*|={float(jnp.linalg.norm(mf.x - xstar)):.2e}")

    # --- one-shot form: every CG variant through the same registry ---
    for name, method, kw in [
        ("PCG (Alg 1)           ", "pcg", {}),
        ("Chronopoulos-Gear     ", "chronopoulos", {}),
        ("PIPECG (Alg 2)        ", "pipecg", {"engine": "jnp"}),
        ("PIPECG + fused kernels", "pipecg", {"engine": "pallas"}),
        ("PIPECG + residual-repl", "pipecg", {"replace_every": 25}),
    ]:
        r = repro.solve(A, b, method=method, M="jacobi", atol=1e-6, maxiter=500, **kw)
        print(
            f"{name}: iters={int(r.iterations):3d}  "
            f"|u|={float(r.residual_norm):.2e}  converged={bool(r.converged)}"
        )
    print("plan cache after the loop:", repro.plan_cache_stats())

    # --- observability: the same solves, now with evidence attached ---
    repro.obs.enable()
    res = p.solve(b)   # warm plan: steady-state timing
    print()
    print(p.last_report.summary())
    repro.obs.disable()


if __name__ == "__main__":
    main()
