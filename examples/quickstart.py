"""Quickstart: solve a 27-pt Poisson system with every CG variant.

Everything goes through the one registry entry point ``repro.solve`` —
methods and kernel engines are configuration, not different APIs.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro import solve
from repro.sparse import poisson27, spmv


def main():
    A = poisson27(16)  # 4096 unknowns, SPD, nnz/N ~ 26
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)  # paper's exact solution 1/sqrt(N)
    b = spmv(A, xstar)

    print(f"A: N={A.n}  nnz/N={A.nnz()/A.n:.1f}  bandwidth={A.bandwidth}")
    for name, method, kw in [
        ("PCG (Alg 1)           ", "pcg", {}),
        ("Chronopoulos-Gear     ", "chronopoulos", {}),
        ("PIPECG (Alg 2)        ", "pipecg", {"engine": "jnp"}),
        ("PIPECG + fused kernels", "pipecg", {"engine": "pallas"}),
        ("PIPECG + residual-repl", "pipecg", {"replace_every": 25}),
    ]:
        res = solve(A, b, method=method, M="jacobi", atol=1e-6, maxiter=500, **kw)
        err = float(jnp.linalg.norm(res.x - xstar))
        print(
            f"{name}: iters={int(res.iterations):3d}  "
            f"|u|={float(res.residual_norm):.2e}  |x-x*|={err:.2e}  "
            f"converged={bool(res.converged)}"
        )


if __name__ == "__main__":
    main()
