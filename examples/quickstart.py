"""Quickstart: plan once, solve many — the plan/execute workflow.

The paper's whole premise is that PIPECG setup (preconditioner, data
decomposition, compiled iteration loop) is paid once while the loop runs
many times. ``repro.plan`` is that split made explicit:

    p = repro.plan(A, method="pipecg", M="jacobi")   # setup, paid once
    p.solve(b)                                        # any number of rhs
    p.solve_batched(B)                                # one vmapped program

``repro.solve`` stays available as the one-shot form (it reuses plans
from a keyed cache under the hood), and matrix-free operators plug into
the same plans via ``FunctionOperator``.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

import repro
from repro.sparse import FunctionOperator, poisson27, spmv


def main():
    A = poisson27(16)  # 4096 unknowns, SPD, nnz/N ~ 26
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)  # paper's exact solution 1/sqrt(N)
    b = spmv(A, xstar)
    print(f"A: N={A.n}  nnz/N={A.nnz()/A.n:.1f}  bandwidth={A.bandwidth}")

    # --- plan once ---
    p = repro.plan(A, method="pipecg", M="jacobi", atol=1e-6, maxiter=500)
    desc = p.describe()
    print("plan:", ", ".join(f"{k}={desc[k]}" for k in ("method", "engine", "preconditioner", "n")))

    # --- ...then serve right-hand sides against the pinned program ---
    res = p.solve(b)
    print(
        f"solve:   iters={int(res.iterations):3d}  |x-x*|="
        f"{float(jnp.linalg.norm(res.x - xstar)):.2e}  traces={p.trace_count}"
    )
    B = jnp.stack([b, 2.0 * b, -0.5 * b, b + 1e-3])
    batch = p.solve_batched(B)  # ONE vmapped XLA program for all four
    print(
        f"batched: {B.shape[0]} rhs in one program, "
        f"iters={[int(i) for i in batch.iterations]}  traces={p.trace_count}"
    )

    # --- matrix-free: the same plan machinery, no materialized matrix ---
    op = FunctionOperator(fn=lambda v: spmv(A, v), n=A.n, out_dtype=b.dtype,
                          diag=A.diagonal())  # diag enables M="jacobi"
    mf = repro.plan(op, method="pipecg", M="jacobi", atol=1e-6, maxiter=500).solve(b)
    print(f"matrix-free FunctionOperator: iters={int(mf.iterations):3d}  "
          f"|x-x*|={float(jnp.linalg.norm(mf.x - xstar)):.2e}")

    # --- one-shot form: every CG variant through the same registry ---
    for name, method, kw in [
        ("PCG (Alg 1)           ", "pcg", {}),
        ("Chronopoulos-Gear     ", "chronopoulos", {}),
        ("PIPECG (Alg 2)        ", "pipecg", {"engine": "jnp"}),
        ("PIPECG + fused kernels", "pipecg", {"engine": "pallas"}),
        ("PIPECG + residual-repl", "pipecg", {"replace_every": 25}),
    ]:
        r = repro.solve(A, b, method=method, M="jacobi", atol=1e-6, maxiter=500, **kw)
        print(
            f"{name}: iters={int(r.iterations):3d}  "
            f"|u|={float(r.residual_norm):.2e}  converged={bool(r.converged)}"
        )
    print("plan cache after the loop:", repro.plan_cache_stats())


if __name__ == "__main__":
    main()
