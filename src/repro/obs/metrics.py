"""Process-local metrics: counters, gauges, histograms.

A deliberately tiny registry — no labels cardinality, no exporters, no
background threads — because the quantity that matters here is *solver*
telemetry: plan-cache hits, traces, solves, iterations, batch occupancy,
padding waste. Everything is a strict no-op while observability is
disabled (``obs.disable()``, the default): ``inc``/``set``/``record``
check the shared enable flag and return, so the hot serving path pays one
predicate per event and the metric values stay exactly zero — the
overhead guard tests assert this.

Sinks: :func:`snapshot` (plain dict), :func:`format_metrics` (human
readable), :func:`dump_jsonl` (one JSON line per metric, grep/jq-able).
"""
from __future__ import annotations

import json
import threading
from typing import Dict, List, Union

from . import trace as _trace

__all__ = [
    "counter",
    "gauge",
    "histogram",
    "metric_names",
    "snapshot",
    "reset_metrics",
    "format_metrics",
    "dump_jsonl",
    "Counter",
    "Gauge",
    "Histogram",
]

_LOCK = threading.Lock()

# histograms keep raw samples for percentiles, capped so a long-lived
# serving process cannot grow without bound (count/sum/min/max stay exact)
_HIST_SAMPLES_MAX = 4096


class Counter:
    """Monotonic event count. ``inc`` is a no-op while obs is disabled."""

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if not _trace.enabled():
            return
        with _LOCK:
            self.value += n

    def to_dict(self) -> dict:
        return {"name": self.name, "kind": self.kind, "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value (queue depth, cache size)."""

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        if not _trace.enabled():
            return
        with _LOCK:
            self.value = float(v)

    def to_dict(self) -> dict:
        return {"name": self.name, "kind": self.kind, "value": self.value}


class Histogram:
    """Distribution summary: count/sum/min/max + capped raw samples."""

    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.samples: List[float] = []

    def record(self, v: float) -> None:
        if not _trace.enabled():
            return
        v = float(v)
        with _LOCK:
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            if len(self.samples) < _HIST_SAMPLES_MAX:
                self.samples.append(v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100], from the retained samples (0.0 when empty)."""
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        idx = min(int(round(q / 100.0 * (len(xs) - 1))), len(xs) - 1)
        return xs[idx]

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }


Metric = Union[Counter, Gauge, Histogram]

_REGISTRY: Dict[str, Metric] = {}


def _get(name: str, cls) -> Metric:
    with _LOCK:
        m = _REGISTRY.get(name)
        if m is None:
            m = _REGISTRY[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {type(m).__name__}, "
                f"not {cls.__name__}"
            )
        return m


def counter(name: str) -> Counter:
    """Get-or-create the counter ``name`` (dotted names by convention)."""
    return _get(name, Counter)


def gauge(name: str) -> Gauge:
    return _get(name, Gauge)


def histogram(name: str) -> Histogram:
    return _get(name, Histogram)


def metric_names() -> tuple:
    with _LOCK:
        return tuple(sorted(_REGISTRY))


def snapshot() -> Dict[str, dict]:
    """{name: metric dict} for every registered metric."""
    with _LOCK:
        items = list(_REGISTRY.items())
    return {name: m.to_dict() for name, m in sorted(items)}


def reset_metrics() -> None:
    """Drop all metrics (values AND registrations) — test/bench hygiene."""
    with _LOCK:
        _REGISTRY.clear()


def format_metrics() -> str:
    """Human-readable dump, one metric per line."""
    lines = []
    for name, d in snapshot().items():
        if d["kind"] == "histogram":
            lines.append(
                f"{name:<40s} hist  count={d['count']:<8g} mean={d['mean']:.4g} "
                f"p50={d['p50']:.4g} p99={d['p99']:.4g} max={d['max']:.4g}"
            )
        else:
            lines.append(f"{name:<40s} {d['kind']:<5s} {d['value']:g}")
    return "\n".join(lines)


def dump_jsonl(path: str) -> None:
    """One JSON object per metric per line (append-friendly, jq-able)."""
    with open(path, "w") as f:
        for d in snapshot().values():
            f.write(json.dumps(d, sort_keys=True) + "\n")
