"""repro.obs — solver telemetry: spans, metrics, solve reports.

The measurement layer the rest of the stack reports through, off by
default and zero-overhead while off:

    import repro.obs as obs

    obs.enable()                      # spans record, metrics count
    p = repro.plan(A, method="pipecg")
    res = p.solve(b)                  # synchronized + timed under a span
    print(p.last_report.summary())    # curve, launches/iter, GB/s, ...
    print(obs.format_metrics())       # plan cache, solves, iterations
    obs.dump_spans("spans.json"); obs.dump_jsonl("metrics.jsonl")

* ``trace``   — host-side span tree; each span also opens a
  ``jax.profiler.TraceAnnotation`` so the same names appear in XLA
  profiles. ``trace_scope`` (``jax.named_scope``) tags *traced* code with
  zero added primitives — the solve loop's jaxpr is byte-identical with
  observability on or off.
* ``metrics`` — process-local counters/gauges/histograms with JSON-lines
  and human-readable sinks; strict no-ops while disabled.
* ``report``  — :class:`SolveReport` built from ``SolveResult`` + plan
  metadata, and :func:`convergence_curve`, the one NaN-trim
  implementation (batched histories return ragged per-row curves).
"""
from __future__ import annotations

from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    counter,
    dump_jsonl,
    format_metrics,
    gauge,
    histogram,
    metric_names,
    reset_metrics,
    snapshot,
)
from .report import (  # noqa: F401
    SolveReport,
    comparable_env,
    convergence_curve,
    env_fingerprint,
    iterations_from_history,
    plan_launches_per_iteration,
    solve_report,
    structural_bytes_per_elem,
)
from .trace import (  # noqa: F401
    Span,
    clear_spans,
    disable,
    dump_spans,
    enable,
    enabled,
    span,
    span_tree,
    spans_to_dicts,
    trace_scope,
)

__all__ = [
    # switch
    "enable", "disable", "enabled",
    # spans
    "span", "trace_scope", "Span", "span_tree", "clear_spans",
    "spans_to_dicts", "dump_spans",
    # metrics
    "counter", "gauge", "histogram", "metric_names", "snapshot",
    "reset_metrics", "format_metrics", "dump_jsonl",
    "Counter", "Gauge", "Histogram",
    # report
    "SolveReport", "solve_report", "convergence_curve",
    "iterations_from_history", "env_fingerprint", "comparable_env",
    "structural_bytes_per_elem", "plan_launches_per_iteration",
]
