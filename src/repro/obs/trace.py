"""Host-side spans + trace-time annotations for the solver stack.

Two complementary instruments, both strict no-ops until ``enable()``:

* :func:`span` — a host-side timed span. Spans nest into a tree (plan
  build > preconditioner resolve > core pinning; solve > execution) and
  each span also opens a ``jax.profiler.TraceAnnotation`` so the same
  region shows up in XLA/perfetto profiles under the same name.
* :func:`trace_scope` — a *trace-time* annotation for code that runs
  under ``jit``/``shard_map``. It wraps ``jax.named_scope``, which tags
  the emitted HLO name stack and **adds zero primitives** to the jaxpr —
  the solver while-loop body is byte-identical with observability on or
  off (asserted in tests via the jaxpr census).

Host spans measure wall time with ``time.perf_counter`` around *host*
work (trace, dispatch); JAX dispatch is async, so a span around a solve
measures end-to-end only if the caller synchronizes — ``SolverPlan.solve``
does exactly that when observability is enabled (and not otherwise, so
the disabled path keeps async dispatch).

State is process-local and thread-safe: each thread keeps its own open
span stack; finished root spans accumulate in one shared list read by
``span_tree()`` / ``dump_spans()``.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "enable",
    "disable",
    "enabled",
    "span",
    "trace_scope",
    "Span",
    "span_tree",
    "clear_spans",
    "spans_to_dicts",
    "dump_spans",
]

_ENABLED = False
_LOCK = threading.Lock()
_ROOTS: List["Span"] = []
_TLS = threading.local()


def enable() -> None:
    """Turn observability on process-wide (spans record, metrics count)."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    """Turn observability off; instruments revert to no-ops."""
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


@dataclass
class Span:
    """One timed region; children are spans opened while it was open."""

    name: str
    attrs: Dict[str, Any] = field(default_factory=dict)
    t_start: float = 0.0
    t_end: float = 0.0
    children: List["Span"] = field(default_factory=list)

    @property
    def duration_s(self) -> float:
        return max(self.t_end - self.t_start, 0.0)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "duration_s": self.duration_s,
            "attrs": dict(self.attrs),
            "children": [c.to_dict() for c in self.children],
        }

    def find(self, name: str) -> Optional["Span"]:
        """First descendant (or self) with this name, depth-first."""
        if self.name == name:
            return self
        for c in self.children:
            hit = c.find(name)
            if hit is not None:
                return hit
        return None


def _stack() -> List[Span]:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


@contextlib.contextmanager
def span(name: str, **attrs):
    """Open a named host span (and an XLA TraceAnnotation) around a block.

    Yields the :class:`Span` (or None when disabled) so callers can attach
    attributes discovered mid-block: ``sp and sp.attrs.update(...)``.
    """
    if not _ENABLED:
        yield None
        return
    sp = Span(name=name, attrs=dict(attrs))
    st = _stack()
    st.append(sp)
    ann = _trace_annotation(name)
    sp.t_start = time.perf_counter()
    try:
        with ann:
            yield sp
    finally:
        sp.t_end = time.perf_counter()
        st.pop()
        if st:
            st[-1].children.append(sp)
        else:
            with _LOCK:
                _ROOTS.append(sp)


def _trace_annotation(name: str):
    # lazy + defensive: profiler availability varies across backends and
    # headless builds; host spans must never fail because of it
    try:
        from jax.profiler import TraceAnnotation

        return TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


def trace_scope(name: str):
    """``jax.named_scope(name)`` when enabled, nullcontext otherwise.

    Safe inside jitted/shard_mapped code: named_scope annotates the HLO
    name stack at trace time and emits no primitives, so the compiled
    program is identical either way — it just becomes *legible* in
    profiles (iteration / reduce / spmv phases get their own names).
    """
    if not _ENABLED:
        return contextlib.nullcontext()
    try:
        import jax

        return jax.named_scope(name)
    except Exception:
        return contextlib.nullcontext()


def span_tree() -> Tuple[Span, ...]:
    """All finished root spans, oldest first."""
    with _LOCK:
        return tuple(_ROOTS)


def clear_spans() -> None:
    with _LOCK:
        _ROOTS.clear()


def spans_to_dicts() -> List[dict]:
    return [s.to_dict() for s in span_tree()]


def dump_spans(path: str) -> None:
    """Write the span tree as JSON (one object, ``{"spans": [...]}``)."""
    with open(path, "w") as f:
        json.dump({"spans": spans_to_dicts()}, f, indent=2)
