"""SolveReport: one solve, every number needed to check a "faster" claim.

The paper's contribution is *measured* — overlap, fusion and the CPU/GPU
decomposition are justified by wall-clock and a performance model — so a
solve result here carries its evidence: the trimmed convergence curve,
iterations-to-tolerance, time-to-solution, kernel launches per iteration
(from the jaxpr census in ``kernels.common``), the structural bytes-moved
model and achieved GB/s against the ``launch/roofline`` HBM peak,
residual-replacement events, plan-cache traffic and an environment
fingerprint that makes trajectory points comparable across runs.

``SolverPlan.solve`` builds one of these automatically when observability
is enabled (``plan.last_report``); :func:`solve_report` is the manual
form. :func:`convergence_curve` is the one NaN-trimming implementation —
``SolveResult.history`` is NaN-padded past convergence and has *no* NaN
tail at exactly-maxiter solves, the off-by-one everyone hand-rolling the
slice gets wrong.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = [
    "convergence_curve",
    "iterations_from_history",
    "env_fingerprint",
    "structural_bytes_per_elem",
    "plan_launches_per_iteration",
    "SolveReport",
    "solve_report",
]


# ---------------------------------------------------------------------------
# convergence-curve trimming (the one implementation)
# ---------------------------------------------------------------------------

def _trim_row(h: np.ndarray) -> np.ndarray:
    nan = np.isnan(h)
    if not nan.any():
        # exactly-maxiter solve: all maxiter+1 entries are real — the
        # whole row IS the curve (slicing to a "first NaN" here is the
        # classic off-by-one that drops the final residual)
        return h
    return h[: int(np.argmax(nan))]


def convergence_curve(result_or_history):
    """Trim the NaN padding from a solve history.

    Accepts a ``SolveResult`` (or anything with ``.history``) or a raw
    history array. A 1-D history returns one ``np.ndarray`` of length
    ``iterations + 1`` (entry 0 is the initial preconditioned residual
    norm); a 2-D (batched) history returns a list of per-row arrays —
    rows converge at different iterations, so the curves are ragged.
    """
    h = getattr(result_or_history, "history", result_or_history)
    h = np.asarray(h, dtype=np.float64)
    if h.ndim == 1:
        return _trim_row(h)
    if h.ndim == 2:
        return [_trim_row(row) for row in h]
    raise ValueError(f"history must be 1-D or 2-D, got shape {h.shape}")


def iterations_from_history(history):
    """Per-solve iteration counts derived from the NaN tail of history.

    1-D -> int; 2-D (k, maxiter+1) -> int array of shape (k,). Works on
    jax or numpy arrays; the 2-D form is what gives batched bucket solves
    honest *per-rhs* iteration counts (every lane of a vmapped solve
    carries its own NaN tail even though wall-clock is shared).
    """
    h = np.asarray(history, dtype=np.float64)
    valid = (~np.isnan(h)).sum(axis=-1)
    iters = np.maximum(valid - 1, 0)
    if h.ndim == 1:
        return int(iters)
    return iters.astype(np.int64)


# ---------------------------------------------------------------------------
# environment fingerprint (what makes two trajectory points comparable)
# ---------------------------------------------------------------------------

def env_fingerprint() -> Dict[str, Any]:
    """Backend/device/precision identity of this process, for records."""
    import platform

    import jax

    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "device_count": len(devs),
        "x64": bool(jax.config.read("jax_enable_x64")),
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
    }


def comparable_env(a: Dict[str, Any], b: Dict[str, Any]) -> bool:
    """Whether wall-clock numbers from two fingerprints may be compared."""
    keys = ("backend", "device_kind", "x64")
    return all(a.get(k) == b.get(k) for k in keys)


# ---------------------------------------------------------------------------
# structural traffic model + census
# ---------------------------------------------------------------------------

def structural_bytes_per_elem(core: str, n_diags: int, elem_bytes: int = 4) -> Optional[float]:
    """Per-iteration HBM bytes/row each core moves BY CONSTRUCTION.

    jnp        — separate passes: SPMV (band + x + y) + 8 triads
                 (2 reads, 1 write each) + PC (3) + 3 dots (2 reads each).
    pallas     — SPMV kernel (band + x + y) + one fused VMA kernel
                 (11 reads + 9 writes).
    fused_iter — ONE kernel: band + m + 8 state vecs + inv_diag reads,
                 9 vector writes (dot partials are noise).

    Returns None for cores the model does not cover (plug-ins).
    """
    vec = {
        "jnp": (n_diags + 2) + 8 * 3 + 3 + 3 * 2,
        "pallas": (n_diags + 2) + (11 + 9),
        "fused_iter": n_diags + 10 + 9,
    }.get(core)
    return None if vec is None else vec * float(elem_bytes)


def plan_launches_per_iteration(plan, b, primitive: str = "pallas_call") -> Optional[int]:
    """Census ``primitive`` occurrences in one iteration of a plan's loop.

    Traces the plan's pinned solve program (no execution) and counts the
    primitive inside the first while-loop body — kernel launches per
    solver iteration. Returns None when the census does not apply (no
    while loop found, or tracing failed for an exotic operator).
    """
    import jax.numpy as jnp

    from ..kernels.common import launches_per_iteration

    atol = jnp.float32(plan.atol)
    rtol = jnp.float32(plan.rtol)
    try:
        if plan.distributed:
            n = launches_per_iteration(plan._run, b, atol, rtol, primitive=primitive)
        else:
            n = launches_per_iteration(
                plan._inner, b, jnp.zeros_like(b), atol, rtol, primitive=primitive
            )
    except Exception:
        return None
    return None if n < 0 else int(n)


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------

@dataclass
class SolveReport:
    """Everything one solve claims, in checkable form."""

    # identity
    method: str
    engine: str
    core: Optional[str]
    operator: str
    n: Optional[int]
    dtype: str
    distributed: bool
    # convergence
    iterations: int
    converged: bool
    residual_norm: float
    curve: np.ndarray  # trimmed, length iterations+1
    # cost
    time_s: Optional[float]
    cold_start: bool  # this solve traced/compiled: wall time is not steady-state
    time_per_iter_s: Optional[float]
    launches_per_iter: Optional[int]
    est_bytes_per_iter: Optional[float]
    achieved_gbs: Optional[float]
    frac_of_hbm_peak: Optional[float]
    # numerics safety net
    replace_every: int
    rr_events: int
    # plan economics
    trace_count: int
    plan_cache: Dict[str, int] = field(default_factory=dict)
    # provenance
    env: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> dict:
        d = {k: v for k, v in self.__dict__.items() if k != "curve"}
        d["curve"] = [float(x) for x in np.asarray(self.curve).ravel()]
        return d

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kwargs)

    def summary(self) -> str:
        """Multi-line human-readable report."""
        lines = [
            f"SolveReport: {self.method}/{self.engine}"
            + (f" core={self.core}" if self.core else "")
            + f"  {self.operator}(n={self.n}, {self.dtype})"
            + ("  [distributed]" if self.distributed else ""),
            f"  convergence : {self.iterations} iters, converged={self.converged}, "
            f"|u|={self.residual_norm:.3e}",
        ]
        if len(self.curve):
            lines.append(
                f"  curve       : {self.curve[0]:.3e} -> {self.curve[-1]:.3e} "
                f"({len(self.curve)} points)"
            )
        if self.time_s is not None:
            per = f", {self.time_per_iter_s*1e6:.1f} us/iter" if self.time_per_iter_s else ""
            cold = "  [cold start: includes trace+compile]" if self.cold_start else ""
            lines.append(f"  time        : {self.time_s*1e3:.3f} ms{per}{cold}")
        if self.launches_per_iter is not None:
            lines.append(f"  launches    : {self.launches_per_iter} kernel(s)/iter (jaxpr census)")
        if self.achieved_gbs is not None:
            lines.append(
                f"  bandwidth   : {self.achieved_gbs:.2f} GB/s achieved "
                f"({self.frac_of_hbm_peak:.1%} of HBM roofline, structural model)"
            )
        if self.replace_every:
            lines.append(
                f"  resid-repl  : every {self.replace_every} iters -> {self.rr_events} event(s)"
            )
        lines.append(
            f"  plan        : trace_count={self.trace_count}, cache={self.plan_cache}"
        )
        return "\n".join(lines)


def solve_report(plan, result, *, elapsed_s: Optional[float] = None, b=None,
                 launches: Optional[int] = None, cold_start: bool = False) -> SolveReport:
    """Build a :class:`SolveReport` from a plan and its ``SolveResult``.

    ``elapsed_s`` is the synchronized wall time of the solve if the caller
    measured one (``SolverPlan.solve`` does, when observability is on);
    ``b`` enables the launches-per-iteration census (any rhs of the right
    shape — the census traces, it does not execute); ``launches`` passes
    an already-censused count instead (plans cache theirs). ``cold_start``
    marks a solve whose wall time includes trace/compile: the report keeps
    the honest end-to-end time but refuses to derive per-iteration time or
    achieved bandwidth from it.
    """
    from ..launch.roofline import HW

    desc = plan.describe()
    iterations = int(np.asarray(result.iterations).max())
    curve = convergence_curve(result)
    if isinstance(curve, list):  # batched result: report the worst lane
        curve = max(curve, key=len)

    core = desc.get("core")
    if launches is None and b is not None:
        launches = plan_launches_per_iteration(plan, b)

    n = desc.get("n")
    est_bpe = None
    if core is not None and hasattr(plan.A, "data"):
        elem = int(np.dtype(np.asarray(plan.A.data).dtype).itemsize)
        est_bpe = structural_bytes_per_elem(core, int(plan.A.data.shape[0]), elem)
    est_bytes = None if (est_bpe is None or n is None) else est_bpe * n

    time_per_iter = achieved = frac = None
    if elapsed_s is not None and iterations > 0 and not cold_start:
        time_per_iter = elapsed_s / iterations
        if est_bytes is not None:
            achieved = est_bytes / time_per_iter / 1e9
            frac = achieved / (HW["hbm_bw"] / 1e9)

    replace_every = int(desc.get("replace_every") or 0)
    rr_events = iterations // replace_every if replace_every > 0 else 0

    from ..plan import plan_cache_stats

    return SolveReport(
        method=desc.get("method", plan.method),
        engine=desc.get("engine", "?"),
        core=core,
        operator=desc.get("operator", type(plan.A).__name__),
        n=n,
        dtype=desc.get("dtype", "?"),
        distributed=bool(desc.get("distributed", False)),
        iterations=iterations,
        converged=bool(np.asarray(result.converged).all()),
        residual_norm=float(np.asarray(result.residual_norm).max()),
        curve=curve,
        time_s=elapsed_s,
        cold_start=cold_start,
        time_per_iter_s=time_per_iter,
        launches_per_iter=launches,
        est_bytes_per_iter=est_bytes,
        achieved_gbs=achieved,
        frac_of_hbm_peak=frac,
        replace_every=replace_every,
        rr_events=rr_events,
        trace_count=plan.trace_count,
        plan_cache=plan_cache_stats(),
        env=env_fingerprint(),
    )
