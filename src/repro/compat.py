"""JAX version compatibility shims.

The repo targets recent JAX (``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``) but must run on older releases
where those live under ``jax.experimental`` or do not exist. Import the
symbols from here instead of from ``jax`` directly:

    from repro.compat import AxisType, make_mesh, shard_map

On older JAX, ``AxisType`` degrades to a no-op enum and ``make_mesh``
silently drops ``axis_types`` (meshes are then fully ``Auto``, which is
what every call site in this repo requests anyway).
"""
from __future__ import annotations

import enum

import jax

__all__ = ["AxisType", "enable_x64", "make_mesh", "shard_map"]


# --- enable_x64 context manager: jax.enable_x64 on new, experimental on old
if hasattr(jax, "enable_x64"):
    enable_x64 = jax.enable_x64
else:  # pragma: no cover - exercised only on older JAX
    from jax.experimental import enable_x64  # type: ignore[no-redef]


# --- shard_map: top-level since jax 0.4.35+/0.5, experimental before -------
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - exercised only on older JAX
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f=None, **kwargs):
        # The experimental version has no replication rule for `while`
        # (which every solver loop here uses), so disable the check — the
        # replicated outputs (psum-produced convergence scalars) really are
        # identical across shards.
        kwargs.setdefault("check_rep", False)
        if f is None:
            return lambda g: _shard_map_exp(g, **kwargs)
        return _shard_map_exp(f, **kwargs)


# --- AxisType: jax.sharding.AxisType on new JAX, no-op enum on old ---------
try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - exercised only on older JAX

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Placeholder for jax.sharding.AxisType on JAX versions without it.

        Old JAX has only Auto-style meshes, so every member is equivalent
        to Auto and only exists so call sites type-check.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """``jax.make_mesh`` that tolerates missing ``axis_types`` support."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names, devices=devices, axis_types=axis_types
        )
    except TypeError:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)
