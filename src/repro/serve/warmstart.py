"""Cross-process warm start: JSON plan manifests ("hot in seconds").

A serving replica's real cold-start cost is not process boot — it is the
first request against every (operator, config) pair paying plan build +
trace + XLA compile. This module serializes a running pool's recipes so
a FRESH process rebuilds and re-traces all its plans at startup instead
of on first traffic:

    save_manifest("plans.json", server.plans())          # on any replica
    srv = SolverServer.from_manifest("plans.json")       # on a new one
    srv.submit(A, b)            # first request: ZERO new traces

A manifest entry is ``(operator spec, plan.config(), plan.describe(),
operator fingerprint)``. Operator specs go through a builder registry —
the stencil/synthetic generators are registered (tiny specs, data
regenerated deterministically), and any ``DIAMatrix`` falls back to
inline band storage. The round-trip contract (test-asserted): a rebuilt
plan's ``describe()`` matches the saved one (sans trace counts) and its
content fingerprint + pool routing key are identical — so a warm
replica's pool routes live traffic onto the rebuilt plans, never beside
them.
"""
from __future__ import annotations

import json
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from ..obs import metrics as _metrics

__all__ = [
    "MANIFEST_VERSION",
    "build_operator",
    "load_manifest",
    "operator_spec",
    "register_operator_builder",
    "save_manifest",
]

MANIFEST_VERSION = 1

_BUILDERS: Dict[str, Callable] = {}


def register_operator_builder(name: str, fn: Callable, *, overwrite: bool = False) -> None:
    """Register ``fn(**params) -> operator`` for manifest operator specs."""
    if name in _BUILDERS and not overwrite:
        raise ValueError(
            f"operator builder {name!r} already registered; pass overwrite=True"
        )
    _BUILDERS[name] = fn


def _dia_inline(offsets, n, data, dtype="float32"):
    import jax.numpy as jnp

    from ..sparse import DIAMatrix

    return DIAMatrix(jnp.asarray(data, dtype=dtype), tuple(offsets), int(n))


def _register_defaults() -> None:
    from ..sparse import (
        poisson7,
        poisson27,
        poisson125,
        poisson_dia,
        synthetic_spd_dia,
        table1_matrix,
    )

    for name, fn in [
        ("dia", _dia_inline),
        ("poisson7", poisson7),
        ("poisson27", poisson27),
        ("poisson125", poisson125),
        ("poisson_dia", poisson_dia),
        ("synthetic", synthetic_spd_dia),
        ("table1", table1_matrix),
    ]:
        if name not in _BUILDERS:
            _BUILDERS[name] = fn


def operator_spec(A, builder: Optional[str] = None, **params) -> dict:
    """The JSON spec a manifest stores for ``A``.

    With ``builder``/``params`` given, records that recipe verbatim (the
    cheap form — e.g. ``operator_spec(A, "poisson27", n=12)``; data is
    regenerated, not shipped). Otherwise a ``DIAMatrix`` is inlined —
    offsets + band data as lists — which round-trips exactly but scales
    with nnz; prefer a builder recipe for big operators.
    """
    from ..sparse import DIAMatrix

    if builder is not None:
        _register_defaults()
        if builder not in _BUILDERS:
            raise KeyError(f"unknown operator builder {builder!r}; "
                           f"have {sorted(_BUILDERS)}")
        return {"builder": builder, "params": params}
    if isinstance(A, DIAMatrix):
        import numpy as np

        return {
            "builder": "dia",
            "params": {
                "offsets": [int(o) for o in A.offsets],
                "n": int(A.n),
                "dtype": str(A.dtype),
                "data": np.asarray(A.data).tolist(),
            },
        }
    raise TypeError(
        f"cannot derive a manifest spec for {type(A).__name__}; pass "
        "builder=/params (register_operator_builder) for non-DIA operators"
    )


def build_operator(spec: dict):
    """Rebuild the operator a spec describes (inverse of operator_spec)."""
    _register_defaults()
    name = spec["builder"]
    if name not in _BUILDERS:
        raise KeyError(f"unknown operator builder {name!r}; have {sorted(_BUILDERS)}")
    return _BUILDERS[name](**spec.get("params", {}))


def _describe_stable(plan) -> dict:
    """describe() minus process-local churn, JSON-normalized.

    Dropping ``trace_count`` and round-tripping through JSON (tuples ->
    lists) makes the dict directly comparable against a deserialized
    manifest entry.
    """
    d = dict(plan.describe())
    d.pop("trace_count", None)
    return json.loads(json.dumps(d, sort_keys=True, default=str))


def save_manifest(path: str, plans: Iterable, *,
                  operator_specs: Optional[Dict[str, dict]] = None,
                  serve: Optional[dict] = None) -> dict:
    """Write the warm-start manifest for ``plans``; returns the dict.

    ``operator_specs`` maps operator fingerprints to builder recipes
    (``operator_spec(A, "poisson27", n=12)``) — plans whose fingerprint
    has no override fall back to inline DIA. ``serve`` carries serving
    configuration (e.g. ``max_batch``) so a replica warms the exact
    bucket program it will run.
    """
    from ..plan import operator_fingerprint

    operator_specs = operator_specs or {}
    entries: List[dict] = []
    for p in plans:
        fp = operator_fingerprint(p.A)
        if fp.startswith("id:"):
            raise ValueError(
                f"operator of plan {p.method!r} has no content fingerprint "
                "(matrix-free?); it cannot warm-start across processes"
            )
        spec = operator_specs.get(fp) or operator_spec(p.A)
        entries.append({
            "fingerprint": fp,
            "operator": spec,
            "config": p.config(),
            "describe": _describe_stable(p),
        })
    manifest = {"version": MANIFEST_VERSION, "serve": serve or {}, "plans": entries}
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    _metrics.counter("serve.warmstart.saved_plans").inc(len(entries))
    return manifest


def load_manifest(path: str, *, warm: bool = True,
                  max_batch: Optional[int] = None,
                  strict: bool = True) -> Tuple[list, dict]:
    """Rebuild every manifest plan; returns ``([(plan, entry_dict)], serve_cfg)``.

    ``warm=True`` re-traces each plan's serving programs right here —
    one single-rhs solve and (when a bucket size is known from
    ``max_batch`` or the manifest's serve config) one bucket solve with
    zero right-hand sides, so the first real request re-traces nothing.
    ``strict`` verifies the round-trip contract: rebuilt fingerprint and
    ``describe()`` must match the saved ones.
    """
    import time as _time

    import jax.numpy as jnp

    from ..plan import operator_fingerprint, plan as _plan

    with open(path) as f:
        manifest = json.load(f)
    if manifest.get("version") != MANIFEST_VERSION:
        raise ValueError(
            f"manifest version {manifest.get('version')!r} != {MANIFEST_VERSION}"
        )
    serve_cfg = dict(manifest.get("serve", {}))
    if max_batch is None:
        max_batch = serve_cfg.get("max_batch")

    out = []
    ops: Dict[str, object] = {}  # fingerprint -> rebuilt operator (shared)
    for entry in manifest["plans"]:
        t0 = _time.perf_counter()
        fp = entry["fingerprint"]
        A = ops.get(fp)
        if A is None:
            A = ops[fp] = build_operator(entry["operator"])
            if strict and operator_fingerprint(A) != fp:
                raise ValueError(
                    f"rebuilt operator fingerprint {operator_fingerprint(A)!r} "
                    f"!= manifest {fp!r}; the spec does not reproduce the operator"
                )
        p = _plan(A, **entry["config"])
        if strict:
            saved = entry["describe"]
            got = _describe_stable(p)
            if got != saved:
                diff = {k: (saved.get(k), got.get(k))
                        for k in set(saved) | set(got) if saved.get(k) != got.get(k)}
                raise ValueError(f"rebuilt plan describe() drifted: {diff}")
        if warm:
            n = A.shape[0]
            zeros = jnp.zeros((n,), A.dtype)
            p.solve(zeros)  # traces + compiles the single-rhs program
            if max_batch and max_batch > 1:
                p.solve_batched(jnp.zeros((int(max_batch), n), A.dtype))
        _metrics.histogram("serve.warmstart.plan_s").record(
            _time.perf_counter() - t0
        )
        _metrics.counter("serve.warmstart.loaded_plans").inc()
        out.append((p, entry))
    return out, serve_cfg
