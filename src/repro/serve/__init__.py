from .engine import ServeConfig, generate, make_decode_step

__all__ = ["ServeConfig", "generate", "make_decode_step"]
