"""repro.serve — batched + async solver serving over the plan cache.

* ``engine``    — ``SolverEngine``: synchronous bucket coalescing over one
  pinned plan (plus the LM generate loop this package started from).
* ``queue``     — bounded admission queue + bucket-closing batch policy
  (full OR timeout), explicit backpressure (``QueueFull``), deadlines.
* ``router``    — pool of warm ``SolverPlan``s keyed by (operator
  fingerprint, method, engine, tolerance bucket); async misses, LRU
  eviction with in-flight pinning.
* ``warmstart`` — JSON plan manifests: a fresh replica rebuilds and
  re-traces every plan at startup ("hot in seconds").
* ``server``    — ``SolverServer``: the façade wiring them together.

Architecture + tuning knobs: docs/serving.md.
"""
from .engine import (
    ServeConfig,
    SolverEngine,
    bucket_waste,
    generate,
    make_decode_step,
    record_bucket,
)
from .queue import (
    DeadlineExceeded,
    QueueFull,
    RequestQueue,
    ServerClosed,
    SolveRequest,
)
from .router import PlanEntry, PlanPool, pool_key, tolerance_bucket
from .server import ServeResult, SolverServer
from .warmstart import (
    build_operator,
    load_manifest,
    operator_spec,
    register_operator_builder,
    save_manifest,
)

__all__ = [
    "DeadlineExceeded",
    "PlanEntry",
    "PlanPool",
    "QueueFull",
    "RequestQueue",
    "ServeConfig",
    "ServeResult",
    "ServerClosed",
    "SolveRequest",
    "SolverEngine",
    "SolverServer",
    "bucket_waste",
    "build_operator",
    "generate",
    "load_manifest",
    "make_decode_step",
    "operator_spec",
    "pool_key",
    "record_bucket",
    "register_operator_builder",
    "save_manifest",
    "tolerance_bucket",
]
