"""Admission queue + batching policy for the async serving tier.

The front half of ``serve.server.SolverServer`` (see docs/serving.md):
requests are admitted into a bounded :class:`RequestQueue` and a worker
pops them in *buckets* — a bucket closes when it reaches ``max_batch``
(full) or when ``max_wait`` has elapsed since its first request arrived
(timeout). That is the request-level version of the paper's overlap
argument: admission and batching proceed while the previous bucket's
solve is still in flight on device, so queue management hides behind
useful compute instead of serializing with it.

Deliberately thread+condvar based, with ``concurrent.futures.Future``
results — no hard asyncio dependency in the core. An asyncio front end
wraps a submitted future with ``asyncio.wrap_future``.

Backpressure is explicit and observable: a full queue raises
:class:`QueueFull` at ``put`` (never silent dropping, never unbounded
growth), a closed queue raises :class:`ServerClosed`, and a request whose
deadline expired before its bucket was served fails with
:class:`DeadlineExceeded`. Every rejection increments a per-reason
``serve.rejects.<reason>`` counter; queue depth, per-request wait time
and bucket close reasons land in ``repro.obs.metrics`` gauges/
histograms/counters (no-ops while observability is disabled).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import List, Optional

from ..obs import metrics as _metrics

__all__ = [
    "DeadlineExceeded",
    "QueueFull",
    "RequestQueue",
    "ServerClosed",
    "SolveRequest",
]


class QueueFull(RuntimeError):
    """Admission rejected: the bounded queue is at ``max_depth``."""


class ServerClosed(RuntimeError):
    """Admission rejected: the queue/server no longer accepts requests."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline expired before its bucket was served."""


def reject(reason: str, n: int = 1) -> None:
    """Count a rejection under ``serve.rejects.<reason>``."""
    _metrics.counter(f"serve.rejects.{reason}").inc(n)


@dataclass
class SolveRequest:
    """One queued right-hand side: payload + tolerance + deadline + future.

    ``deadline`` is an absolute ``time.monotonic()`` instant (None = no
    deadline). ``future`` resolves to the per-request result the server
    builds from its bucket's solve; callers block on it (or wrap it for
    asyncio).
    """

    b: object
    atol: float
    rtol: float = 0.0
    deadline: Optional[float] = None
    future: Future = field(default_factory=Future)
    enqueued_at: float = field(default_factory=time.monotonic)


class RequestQueue:
    """Bounded FIFO admission queue with a bucket-closing pop policy.

    * ``put`` — O(1) admit; raises :class:`QueueFull` past ``max_depth``
      and :class:`ServerClosed` after :meth:`close` (both counted).
    * ``next_batch(max_batch, max_wait)`` — block for the next bucket:
      the bucket closes on ``max_batch`` requests (``closed_full``) or
      ``max_wait`` seconds after its FIRST request arrived
      (``closed_timeout``), whichever comes first. Requests whose
      deadline already passed are failed + counted, not returned.
    * ``close`` — stop admitting; queued requests still drain (graceful
      shutdown leaves zero dropped requests). ``next_batch`` returns
      ``None`` once closed *and* drained.
    """

    def __init__(self, max_depth: int = 256, name: str = "serve.queue"):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = int(max_depth)
        self.name = name
        self._items: deque = deque()
        self._cond = threading.Condition()
        self._closed = False

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def put(self, req: SolveRequest) -> None:
        with self._cond:
            if self._closed:
                reject("shutdown")
                raise ServerClosed(f"{self.name} is closed to new requests")
            if len(self._items) >= self.max_depth:
                reject("queue_full")
                raise QueueFull(
                    f"{self.name} at max_depth={self.max_depth}; retry later "
                    "(backpressure, not silent queue growth)"
                )
            self._items.append(req)
            _metrics.gauge(f"{self.name}.depth").set(len(self._items))
            self._cond.notify()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def next_batch(self, max_batch: int, max_wait: float) -> Optional[List[SolveRequest]]:
        """Pop the next bucket (see class docstring). ``None`` = drained+closed.

        May return an empty list when every popped request had an expired
        deadline — callers just loop.
        """
        with self._cond:
            while not self._items and not self._closed:
                self._cond.wait(0.05)
            if not self._items:
                return None  # closed and fully drained
            batch = [self._items.popleft()]
            t_close = batch[0].enqueued_at + max_wait
            while len(batch) < max_batch:
                if self._items:
                    batch.append(self._items.popleft())
                    continue
                now = time.monotonic()
                if self._closed or now >= t_close:
                    break
                self._cond.wait(min(t_close - now, 0.05))
            _metrics.gauge(f"{self.name}.depth").set(len(self._items))
            _metrics.counter(
                f"{self.name}.closed_full" if len(batch) >= max_batch
                else f"{self.name}.closed_timeout"
            ).inc()
        now = time.monotonic()
        live: List[SolveRequest] = []
        for r in batch:
            if r.deadline is not None and now > r.deadline:
                reject("deadline")
                r.future.set_exception(DeadlineExceeded(
                    f"deadline passed {now - r.deadline:.3f}s before the "
                    "bucket was served"
                ))
                continue
            _metrics.histogram(f"{self.name}.wait_ms").record(
                (now - r.enqueued_at) * 1e3
            )
            live.append(r)
        return live

    def fail_all(self, exc: BaseException) -> int:
        """Fail every queued request (plan build error); returns the count."""
        with self._cond:
            items, self._items = list(self._items), deque()
            _metrics.gauge(f"{self.name}.depth").set(0)
        for r in items:
            r.future.set_exception(exc)
        reject("plan_error", len(items))
        return len(items)
