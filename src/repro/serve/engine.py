"""Batched serving engines.

LM serving: prefill once, decode greedily/with temperature. The decode
loop is a single jitted ``lax.while_loop`` (token-at-a-time with the
family's cache/state), so serving lowers to one XLA program — the form
the dry-run compiles for decode_32k / long_500k.

Solver serving: ``SolverEngine`` wraps one ``repro.plan`` — operator,
preconditioner, decomposition, sharding and the compiled loop are pinned
at construction — and serves many right-hand sides: single solves hit the
plan's pinned program, batches are vmapped into one XLA program, and
``max_batch`` coalesces arbitrary request batches into fixed-size padded
buckets so steady-state traffic compiles exactly two programs (single +
bucket) no matter the arrival pattern.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.zoo import ModelApi
from ..obs import metrics as _metrics
from ..obs.trace import enabled as _obs_enabled, span as _span

__all__ = [
    "ServeConfig",
    "SolverEngine",
    "bucket_waste",
    "generate",
    "make_decode_step",
    "record_bucket",
]


def record_bucket(valid: int, size: int) -> None:
    """Per-bucket occupancy accounting, shared by every batching path.

    One call per compiled bucket execution: ``valid`` live rhs out of
    ``size`` lanes. Feeds the ``serve.buckets`` / ``serve.padded_lanes``
    counters and the ``serve.batch_occupancy`` histogram — the numbers
    the async tier's batcher (``serve.queue``) and ``SolverEngine`` both
    report, so occupancy is always per *bucket*, never per call.
    """
    _metrics.counter("serve.buckets").inc()
    _metrics.counter("serve.padded_lanes").inc(size - valid)
    _metrics.histogram("serve.batch_occupancy").record(valid / size)


def bucket_waste(iters, step: int) -> int:
    """Lane-iterations wasted by each bucket's shared worst-case stop.

    ``iters`` are per-rhs iteration counts in submission order; lanes ride
    until the slowest rhs of their OWN ``step``-sized bucket stops, so the
    per-bucket ``max - it`` sum is pure occupancy waste — the number
    difficulty-aware routing should shrink.
    """
    import numpy as np

    iters = np.asarray(iters).ravel()
    step = max(int(step), 1)
    return sum(
        int((grp.max() - grp).sum())
        for lo in range(0, len(iters), step)
        if len(grp := iters[lo : lo + step])
    )


@dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = -1          # -1 => never stop early


def make_decode_step(api: ModelApi):
    """decode_step(params, token, cache, pos) — the serve_step the dry-run
    lowers for decode shapes."""

    def decode_step(params, token, cache, pos):
        return api.decode(params, token, cache, pos)

    return decode_step


def generate(api: ModelApi, params, batch: dict, sc: ServeConfig = ServeConfig(), key=None):
    """Prefill on batch["tokens"] then generate sc.max_new_tokens more.

    Returns (tokens (B, T+new), per-step logits of the generated part)."""
    cfg = api.cfg
    B, T = batch["tokens"].shape
    max_seq = T + sc.max_new_tokens
    if key is None:
        key = jax.random.PRNGKey(0)

    # prefill: run the full forward once, build the cache at max_seq length
    logits, pf_cache = api.prefill(params, batch)
    cache = api.init_cache(B, max_seq)
    cache = _copy_prefill(api, cache, pf_cache, T, batch)

    last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    def sample(lg, k):
        if sc.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / sc.temperature).astype(jnp.int32)

    def body(carry):
        i, tok, cache, out, key, done = carry
        lg, cache = api.decode(params, tok[:, None], cache, T + i)
        key, sub = jax.random.split(key)
        nxt = sample(lg[:, 0].astype(jnp.float32), sub)
        nxt = jnp.where(done, tok, nxt)
        done = done | (nxt == sc.eos_id)
        out = out.at[:, i].set(nxt)
        return i + 1, nxt, cache, out, key, done

    def cond(carry):
        i, _, _, _, _, done = carry
        return (i < sc.max_new_tokens) & ~jnp.all(done)

    out0 = jnp.zeros((B, sc.max_new_tokens), jnp.int32)
    done0 = jnp.zeros((B,), bool)
    _, _, _, out, _, _ = jax.lax.while_loop(cond, body, (0, last, cache, out0, key, done0))
    return jnp.concatenate([batch["tokens"], last[:, None], out[:, :-1]], axis=1)


def _copy_prefill(api: ModelApi, cache, pf_cache, T: int, batch: dict):
    """Splice prefill-produced KV/state into a max_seq-sized cache."""
    cfg = api.cfg
    fam = cfg.family
    if fam in ("dense", "moe"):
        k = jax.lax.dynamic_update_slice(cache.k, pf_cache.k, (0, 0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, pf_cache.v, (0, 0, 0, 0, 0))
        return type(cache)(k=k, v=v)
    if fam == "ssm":
        return pf_cache  # recurrent state has no sequence axis
    if fam == "hybrid":
        big = cache.attn_kv
        k = jax.lax.dynamic_update_slice(big.k, pf_cache.attn_kv.k, (0, 0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(big.v, pf_cache.attn_kv.v, (0, 0, 0, 0, 0))
        return pf_cache._replace(attn_kv=type(big)(k=k, v=v))
    if fam == "encdec":
        k = jax.lax.dynamic_update_slice(cache.self_kv.k, pf_cache.self_kv.k, (0, 0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.self_kv.v, pf_cache.self_kv.v, (0, 0, 0, 0, 0))
        return cache._replace(self_kv=type(cache.self_kv)(k=k, v=v), enc_out=pf_cache.enc_out)
    if fam == "vlm":
        k = jax.lax.dynamic_update_slice(cache.self_kv.k, pf_cache.self_kv.k, (0, 0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.self_kv.v, pf_cache.self_kv.v, (0, 0, 0, 0, 0))
        return cache._replace(self_kv=type(cache.self_kv)(k=k, v=v), img_feats=batch["img_feats"])
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# solver serving (repro.solve registry)
# ---------------------------------------------------------------------------

class SolverEngine:
    """Serve many right-hand sides against one pinned ``SolverPlan``.

    Construction builds the plan — preconditioner resolution, perf-model
    decomposition, operator sharding and the compiled loop all happen
    exactly once; ``solve``/``solve_batch`` then accept arbitrary rhs
    traffic:

        eng = SolverEngine(A, method="pipecg", engine="pallas", atol=1e-6)
        res  = eng.solve(b)            # one rhs, pinned program
        many = eng.solve_batch(B)      # (k, n): ONE vmapped XLA program

    ``max_batch`` turns on request coalescing: incoming batches are split
    into buckets of exactly ``max_batch`` rhs (the final partial bucket is
    zero-padded to size), so any traffic pattern executes the same two
    compiled programs — the paper's setup-once economics applied to the
    serving tier. Distributed methods (h1..h4/pl2/pl3) are served through
    the same plan (operator sharded once, at construction); batches run as
    ONE program with the loop vmapped inside the shard_map block, so they
    are never re-split into ``max_batch`` buckets here.

    This engine is the synchronous core the async tier composes:
    ``serve.server.SolverServer`` puts an admission queue, a batching
    policy and a plan-pool router in front of the same bucket economics
    (see docs/serving.md).
    """

    def __init__(
        self,
        A,
        M="jacobi",
        method: str = "pipecg",
        engine: str = "auto",
        atol: float = 1e-5,
        rtol: float = 0.0,
        maxiter: int = 10000,
        max_batch: Optional[int] = None,
        **method_kwargs,
    ):
        from ..plan import plan  # lazy: keep serve importable without solver deps

        if max_batch is not None and max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.plan = plan(
            A, method=method, engine=engine, M=M,
            atol=atol, rtol=rtol, maxiter=maxiter, **method_kwargs,
        )
        self.max_batch = max_batch

    @property
    def A(self):
        return self.plan.A

    def describe(self) -> dict:
        d = self.plan.describe()
        d["max_batch"] = self.max_batch
        return d

    def solve(self, b: jax.Array):
        """Solve for a single rhs ``b`` of shape (n,)."""
        _metrics.counter("serve.requests").inc()
        with _span("serve.solve", n=b.shape[0]):
            return self.plan.solve(b)

    def solve_batch(self, bs: jax.Array):
        """Solve a batch of rhs, shape (k, n) -> SolveResult with leading k.

        Wall-clock for a bucket is set by its slowest rhs, so the batch
        runs to the shared worst-case stop; the returned ``iterations``
        are nevertheless honest *per-rhs* counts, derived from the first
        NaN-tail index of each ``history`` row (today that agrees with
        vmap's per-lane freeze; the derivation stays correct under
        execution strategies with no such freeze, e.g. mesh-level rhs
        stacking). Group rhs of similar difficulty when latency matters —
        the ``serve.*`` batch-occupancy/waste metrics quantify the cost
        of not doing so.
        """
        k = bs.shape[0]
        _metrics.counter("serve.requests").inc(k)
        with _span("serve.solve_batch", k=k):
            out = self._solve_batch_impl(bs)
        return self._with_per_rhs_iterations(out)

    def _solve_batch_impl(self, bs: jax.Array):
        if self.max_batch is None or self.plan.distributed or bs.shape[0] == 0:
            # one un-split bucket of size k: still a bucket execution, so
            # it still reports occupancy (full, zero pads) — per-bucket
            # accounting must not vanish just because no split happened
            if bs.shape[0]:
                record_bucket(bs.shape[0], bs.shape[0])
            return self.plan.solve_batched(bs)
        k = bs.shape[0]
        chunks = []
        for lo in range(0, k, self.max_batch):
            chunk = bs[lo : lo + self.max_batch]
            valid = chunk.shape[0]
            pad = self.max_batch - valid
            if pad:  # coalesce the remainder into the SAME compiled bucket
                chunk = jnp.concatenate([chunk, jnp.zeros((pad, bs.shape[1]), bs.dtype)])
            record_bucket(valid, self.max_batch)
            chunks.append(self.plan.solve_batched(chunk))
        out = jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *chunks)
        return jax.tree_util.tree_map(lambda x: x[:k], out)

    def _with_per_rhs_iterations(self, out):
        """Replace ``iterations`` with per-rhs counts from the NaN tails.

        Computed lazily in jnp (no host sync on the serving path). With
        observability on, also records the per-rhs iteration spread and
        the lane-iterations wasted by the shared worst-case stop.
        """
        hist = out.history
        if hist.ndim < 2 or hist.shape[0] == 0:
            return out
        per_rhs = jnp.maximum(jnp.sum(~jnp.isnan(hist), axis=-1) - 1, 0).astype(jnp.int32)
        out = dataclasses.replace(out, iterations=per_rhs)
        if _obs_enabled():
            import numpy as np

            iters = np.asarray(per_rhs)
            for it in iters:
                _metrics.histogram("serve.rhs_iterations").record(int(it))
            # waste is accounted per BUCKET (mirroring _solve_batch_impl's
            # split), not per call: a k=10/max_batch=4 batch reports three
            # buckets' worth, and an un-split batch (max_batch=None, or a
            # distributed batch — since mesh-level rhs stacking those also
            # run as ONE program with a shared worst-case stop) reports
            # one k-sized bucket.
            step = len(iters)
            if self.max_batch is not None and not self.plan.distributed:
                step = self.max_batch
            _metrics.counter("serve.wasted_lane_iterations").inc(bucket_waste(iters, step))
        return out
