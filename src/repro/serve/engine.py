"""Batched serving engines.

LM serving: prefill once, decode greedily/with temperature. The decode
loop is a single jitted ``lax.while_loop`` (token-at-a-time with the
family's cache/state), so serving lowers to one XLA program — the form
the dry-run compiles for decode_32k / long_500k.

Solver serving: ``SolverEngine`` pins one operator + method/engine choice
from the ``repro.solve`` registry and serves many right-hand sides —
single solves reuse the jit cache (same A pytree structure), batches are
vmapped into one XLA program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.zoo import ModelApi

__all__ = ["ServeConfig", "SolverEngine", "generate", "make_decode_step"]


@dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = -1          # -1 => never stop early


def make_decode_step(api: ModelApi):
    """decode_step(params, token, cache, pos) — the serve_step the dry-run
    lowers for decode shapes."""

    def decode_step(params, token, cache, pos):
        return api.decode(params, token, cache, pos)

    return decode_step


def generate(api: ModelApi, params, batch: dict, sc: ServeConfig = ServeConfig(), key=None):
    """Prefill on batch["tokens"] then generate sc.max_new_tokens more.

    Returns (tokens (B, T+new), per-step logits of the generated part)."""
    cfg = api.cfg
    B, T = batch["tokens"].shape
    max_seq = T + sc.max_new_tokens
    if key is None:
        key = jax.random.PRNGKey(0)

    # prefill: run the full forward once, build the cache at max_seq length
    logits, pf_cache = api.prefill(params, batch)
    cache = api.init_cache(B, max_seq)
    cache = _copy_prefill(api, cache, pf_cache, T, batch)

    last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    def sample(lg, k):
        if sc.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / sc.temperature).astype(jnp.int32)

    def body(carry):
        i, tok, cache, out, key, done = carry
        lg, cache = api.decode(params, tok[:, None], cache, T + i)
        key, sub = jax.random.split(key)
        nxt = sample(lg[:, 0].astype(jnp.float32), sub)
        nxt = jnp.where(done, tok, nxt)
        done = done | (nxt == sc.eos_id)
        out = out.at[:, i].set(nxt)
        return i + 1, nxt, cache, out, key, done

    def cond(carry):
        i, _, _, _, _, done = carry
        return (i < sc.max_new_tokens) & ~jnp.all(done)

    out0 = jnp.zeros((B, sc.max_new_tokens), jnp.int32)
    done0 = jnp.zeros((B,), bool)
    _, _, _, out, _, _ = jax.lax.while_loop(cond, body, (0, last, cache, out0, key, done0))
    return jnp.concatenate([batch["tokens"], last[:, None], out[:, :-1]], axis=1)


def _copy_prefill(api: ModelApi, cache, pf_cache, T: int, batch: dict):
    """Splice prefill-produced KV/state into a max_seq-sized cache."""
    cfg = api.cfg
    fam = cfg.family
    if fam in ("dense", "moe"):
        k = jax.lax.dynamic_update_slice(cache.k, pf_cache.k, (0, 0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, pf_cache.v, (0, 0, 0, 0, 0))
        return type(cache)(k=k, v=v)
    if fam == "ssm":
        return pf_cache  # recurrent state has no sequence axis
    if fam == "hybrid":
        big = cache.attn_kv
        k = jax.lax.dynamic_update_slice(big.k, pf_cache.attn_kv.k, (0, 0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(big.v, pf_cache.attn_kv.v, (0, 0, 0, 0, 0))
        return pf_cache._replace(attn_kv=type(big)(k=k, v=v))
    if fam == "encdec":
        k = jax.lax.dynamic_update_slice(cache.self_kv.k, pf_cache.self_kv.k, (0, 0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.self_kv.v, pf_cache.self_kv.v, (0, 0, 0, 0, 0))
        return cache._replace(self_kv=type(cache.self_kv)(k=k, v=v), enc_out=pf_cache.enc_out)
    if fam == "vlm":
        k = jax.lax.dynamic_update_slice(cache.self_kv.k, pf_cache.self_kv.k, (0, 0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.self_kv.v, pf_cache.self_kv.v, (0, 0, 0, 0, 0))
        return cache._replace(self_kv=type(cache.self_kv)(k=k, v=v), img_feats=batch["img_feats"])
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# solver serving (repro.solve registry)
# ---------------------------------------------------------------------------

class SolverEngine:
    """Serve many right-hand sides against one pinned operator.

    The operator, preconditioner, method and engine are fixed at
    construction (amortizing jit compilation across requests);
    ``solve``/``solve_batch`` then accept arbitrary rhs traffic:

        eng = SolverEngine(A, method="pipecg", engine="pallas", atol=1e-6)
        res  = eng.solve(b)            # one rhs
        many = eng.solve_batch(B)      # (k, n): ONE vmapped XLA program

    Distributed methods (h1/h2/h3) are served too, but each request runs
    sequentially (shard_map does not nest under vmap) and currently
    re-shards the operator per call — an operator-handle cache is a
    ROADMAP item; size latency-sensitive deployments accordingly.
    """

    def __init__(
        self,
        A,
        M="jacobi",
        method: str = "pipecg",
        engine: str = "auto",
        atol: float = 1e-5,
        rtol: float = 0.0,
        maxiter: int = 10000,
        **method_kwargs,
    ):
        from ..api import solve  # lazy: keep serve importable without solver deps
        from ..core.distributed import method_names

        self._solve = solve
        self.A = A
        self.M = M
        self.method = method
        self.engine = engine
        self.atol = atol
        self.rtol = rtol
        self.maxiter = maxiter
        self.method_kwargs = method_kwargs
        self._distributed = method in method_names() or method == "pipecg_distributed"
        self._vmapped = None

    def solve(self, b: jax.Array):
        """Solve for a single rhs ``b`` of shape (n,)."""
        return self._solve(
            self.A, b, method=self.method, engine=self.engine, M=self.M,
            atol=self.atol, rtol=self.rtol, maxiter=self.maxiter, **self.method_kwargs,
        )

    def solve_batch(self, bs: jax.Array):
        """Solve a batch of rhs, shape (k, n) -> SolveResult with leading k.

        Per-lane results are exact (vmap's while_loop rule freezes a lane's
        state once its own convergence test fires, so iterations/history are
        per-rhs), but wall-clock is set by the slowest rhs in the batch —
        group rhs of similar difficulty when latency matters.
        """
        if self._distributed:
            results = [self.solve(b) for b in bs]
            return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *results)
        if self._vmapped is None:
            self._vmapped = jax.vmap(self.solve)
        return self._vmapped(bs)
