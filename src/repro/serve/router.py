"""Plan-pool router: warm ``SolverPlan``s keyed by (operator, config, tol).

The middle of the async serving tier (docs/serving.md). A
:class:`PlanPool` holds entries keyed by

    (operator fingerprint, method, engine, M, tolerance bucket,
     maxiter, extra plan kwargs)

— :func:`repro.plan.operator_fingerprint` is *content*-based, so the same
matrix built in two processes routes to the same key (what warm-start
manifests rely on). Tolerances are bucketed by decade
(:func:`tolerance_bucket`): requests in the same decade share one plan
and are batched together; a bucket's batch is solved at the tightest
tolerance in it, so no request is ever solved looser than it asked.

A pool miss builds the plan **asynchronously** on a builder thread —
traffic routed to already-warm plans never blocks behind a cold build
(the request-level form of the paper's communication hiding; the miss's
own requests queue behind the entry's ``ready`` event). Eviction is LRU
with in-flight pinning: an entry being served (``entry.pinned()``) or
still building is never evicted; victims go through the pool's
``on_evict`` hook so the serving layer can drain their queues gracefully.
"""
from __future__ import annotations

import math
import threading
from collections import OrderedDict
from contextlib import contextmanager
from typing import Callable, Optional, Tuple

from ..obs import metrics as _metrics

__all__ = ["PlanEntry", "PlanPool", "pool_key", "tolerance_bucket"]


def tolerance_bucket(atol: float) -> float:
    """Decade bucket for a tolerance: 3e-6 -> 1e-6, 5e-5 -> 1e-5.

    The bucket's nominal value is the decade's lower edge, so a batch
    solved at it is at least as tight as every request it carries.
    Non-positive tolerances (pure rtol / run-to-maxiter) map to 0.0.
    """
    if atol is None or atol <= 0.0:
        return 0.0
    return 10.0 ** math.floor(math.log10(atol))


def pool_key(fingerprint: str, config: dict) -> tuple:
    """The pool's routing key for an operator fingerprint + plan config.

    ``config`` is the :meth:`SolverPlan.config` shape (method/engine/M/
    atol/rtol/maxiter + extra kwargs). Stable across processes for
    content-fingerprinted operators — the warm-start round-trip test
    asserts a manifest-rebuilt plan lands on the identical key.
    """
    cfg = dict(config)
    method = cfg.pop("method", "pipecg")
    engine = cfg.pop("engine", "auto")
    M = cfg.pop("M", "jacobi")
    atol = cfg.pop("atol", 1e-5)
    rtol = cfg.pop("rtol", 0.0)
    maxiter = cfg.pop("maxiter", 10000)
    extras = tuple(sorted((k, v) for k, v in cfg.items() if v is not None))
    if rtol:
        extras += (("rtol", float(rtol)),)
    return (fingerprint, method, engine, M, tolerance_bucket(atol),
            int(maxiter), extras)


class PlanEntry:
    """One pooled plan: key, build state, pin count.

    ``plan`` is None until the builder thread finishes; waiters block on
    ``ready`` and then check ``error``. ``pinned()`` guards an in-flight
    solve against eviction.
    """

    def __init__(self, key: tuple, config: dict):
        self.key = key
        self.config = dict(config)
        self.plan = None
        self.error: Optional[BaseException] = None
        self.ready = threading.Event()
        self.build_s: Optional[float] = None
        self._pins = 0
        self._lock = threading.Lock()

    @property
    def tol(self) -> float:
        """The tolerance this entry's buckets are solved at (decade edge)."""
        return self.key[4]

    @property
    def pins(self) -> int:
        with self._lock:
            return self._pins

    @contextmanager
    def pinned(self):
        with self._lock:
            self._pins += 1
        try:
            yield self
        finally:
            with self._lock:
                self._pins -= 1

    def wait(self, timeout: Optional[float] = None):
        """Block until built; returns the plan or raises the build error."""
        if not self.ready.wait(timeout):
            raise TimeoutError(f"plan build for {self.key!r} still running")
        if self.error is not None:
            raise self.error
        return self.plan


class PlanPool:
    """LRU pool of warm plans with async builds and pinned eviction.

    ``get_or_create(A, config)`` routes to the existing entry (hit) or
    inserts a building entry and kicks a daemon builder thread (miss) —
    the call never blocks on compilation, so warm-plan traffic keeps
    flowing while a cold plan traces. ``adopt`` inserts an already-built
    plan under the same key a ``get_or_create`` would compute (the
    warm-start path). ``on_evict(entry)`` fires outside the pool lock.
    """

    def __init__(self, max_plans: int = 8,
                 on_evict: Optional[Callable[[PlanEntry], None]] = None):
        if max_plans < 1:
            raise ValueError(f"max_plans must be >= 1, got {max_plans}")
        self.max_plans = int(max_plans)
        self.on_evict = on_evict
        self._entries: "OrderedDict[tuple, PlanEntry]" = OrderedDict()
        self._lock = threading.Lock()
        self._fp_cache: dict = {}  # id(A) -> (A, fingerprint)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def entries(self) -> Tuple[PlanEntry, ...]:
        with self._lock:
            return tuple(self._entries.values())

    def fingerprint(self, A) -> str:
        """Content fingerprint of ``A``, memoized per live object."""
        from ..plan import operator_fingerprint

        hit = self._fp_cache.get(id(A))
        if hit is not None and hit[0] is A:
            return hit[1]
        fp = operator_fingerprint(A)
        if len(self._fp_cache) > 4 * self.max_plans:  # stale-id hygiene
            self._fp_cache.clear()
        self._fp_cache[id(A)] = (A, fp)
        return fp

    def lookup(self, key: tuple) -> Optional[PlanEntry]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
            return entry

    def get_or_create(self, A, config: dict) -> Tuple[PlanEntry, bool]:
        """Route to the entry for (A, config); returns (entry, created).

        On a miss the entry is inserted immediately (so concurrent
        requests pile onto ONE build) and a daemon thread builds the
        plan; ``entry.ready``/``entry.error`` publish the outcome.
        """
        key = pool_key(self.fingerprint(A), config)
        evicted = []
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                _metrics.counter("serve.router.hits").inc()
                return entry, False
            _metrics.counter("serve.router.misses").inc()
            entry = PlanEntry(key, config)
            self._entries[key] = entry
            evicted = self._evict_locked()
            _metrics.gauge("serve.router.plans").set(len(self._entries))
        for victim in evicted:
            self._notify_evict(victim)
        threading.Thread(
            target=self._build, args=(entry, A),
            name=f"plan-build-{key[0][:8]}", daemon=True,
        ).start()
        return entry, True

    def adopt(self, A, plan) -> PlanEntry:
        """Insert an already-built plan (warm start) under its routing key."""
        config = plan.config()
        key = pool_key(self.fingerprint(A), config)
        entry = PlanEntry(key, config)
        entry.plan = plan
        entry.ready.set()
        evicted = []
        with self._lock:
            existing = self._entries.get(key)
            if existing is not None:
                self._entries.move_to_end(key)
                return existing
            self._entries[key] = entry
            evicted = self._evict_locked()
            _metrics.gauge("serve.router.plans").set(len(self._entries))
        for victim in evicted:
            self._notify_evict(victim)
        return entry

    def _build(self, entry: PlanEntry, A) -> None:
        import time as _time

        from ..plan import plan as _plan

        t0 = _time.perf_counter()
        try:
            entry.plan = _plan(A, **entry.config)
        except BaseException as e:  # publish, don't kill the thread silently
            entry.error = e
            _metrics.counter("serve.router.build_errors").inc()
        finally:
            entry.build_s = _time.perf_counter() - t0
            _metrics.histogram("serve.router.build_s").record(entry.build_s)
            entry.ready.set()

    def _evict_locked(self) -> list:
        """LRU eviction skipping pinned/building entries; returns victims."""
        victims = []
        while len(self._entries) > self.max_plans:
            victim_key = None
            for key, entry in self._entries.items():  # LRU order
                if entry.pins == 0 and entry.ready.is_set():
                    victim_key = key
                    break
            if victim_key is None:
                # everything pinned or building: soft cap, try again later
                _metrics.counter("serve.router.evict_blocked").inc()
                break
            victims.append(self._entries.pop(victim_key))
            _metrics.counter("serve.router.evictions").inc()
        return victims

    def _notify_evict(self, entry: PlanEntry) -> None:
        if self.on_evict is not None:
            try:
                self.on_evict(entry)
            except Exception:
                pass
