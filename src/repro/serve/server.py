"""``SolverServer`` — the async serving tier's front door.

Composes the subsystem (docs/serving.md): an admission queue + batching
policy per plan (``serve.queue``), a plan-pool router with async builds
and LRU eviction (``serve.router``), and the pinned-plan bucket
economics of ``serve.engine``. The paper's thesis applied at the request
level: the solver hot loop stays saturated while admission, batching and
cold plan builds all overlap with in-flight solves.

    srv = SolverServer(max_batch=8, max_wait_ms=2.0)
    fut = srv.submit(A, b, atol=1e-6)        # non-blocking admission
    res = fut.result()                       # ServeResult: x, iterations…
    srv.shutdown(drain=True)                 # zero dropped requests

Steady-state traffic compiles exactly TWO XLA programs per plan — the
single-rhs program (buckets of one) and the ``max_batch`` bucket program
(everything else, padded to size) — no matter the arrival pattern; the
CI smoke asserts this via ``plan.trace_count``. Per-request iteration
counts are honest even though a bucket runs to its slowest member: they
are derived from each history row's NaN tail (the ``SolveReport``
machinery). ``SolverServer.from_manifest`` warm-starts a fresh replica
from a saved manifest so its first request re-traces nothing.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from ..obs import metrics as _metrics
from .engine import bucket_waste, record_bucket
from .queue import RequestQueue, ServerClosed, SolveRequest, reject
from .router import PlanEntry, PlanPool

__all__ = ["ServeResult", "SolverServer"]


@dataclass(frozen=True)
class ServeResult:
    """Per-request outcome, sliced out of its bucket's batched solve."""

    x: object
    iterations: int
    converged: bool
    residual_norm: float
    queue_wait_s: float      # admission -> bucket close
    solve_s: float           # bucket wall-clock (shared by its bucket)
    bucket_size: int         # live requests in the bucket (1 = single program)
    bucket_occupancy: float  # live / compiled lanes


class _PlanWorker:
    """One plan's serving loop: queue -> buckets -> pinned programs."""

    def __init__(self, server: "SolverServer", entry: PlanEntry):
        self.server = server
        self.entry = entry
        self.queue = RequestQueue(max_depth=server.max_depth)
        self.idle = threading.Event()
        self.idle.set()
        self.thread = threading.Thread(
            target=self._run, name=f"plan-serve-{entry.key[0][:8]}", daemon=True
        )
        self.thread.start()

    def _run(self) -> None:
        self.entry.ready.wait()
        if self.entry.error is not None:
            # the plan never built: fail whatever queued (and keeps queuing
            # until the router's miss path stops routing here)
            while True:
                self.queue.fail_all(self.entry.error)
                if self.queue.closed and len(self.queue) == 0:
                    return
                time.sleep(0.01)
        while True:
            batch = self.queue.next_batch(self.server.max_batch,
                                          self.server.max_wait_ms / 1e3)
            if batch is None:
                return  # closed + drained
            if not batch:
                continue  # every popped request had an expired deadline
            self.idle.clear()
            try:
                with self.entry.pinned():
                    self._serve(batch)
            finally:
                self.idle.set()

    def _serve(self, batch: List[SolveRequest]) -> None:
        import jax.numpy as jnp
        import numpy as np

        from ..obs.report import iterations_from_history

        plan = self.entry.plan
        k = len(batch)
        atol = min(r.atol for r in batch)  # tightest in the tolerance bucket
        rtol = min(r.rtol for r in batch)
        t0 = time.monotonic()  # same clock as SolveRequest.enqueued_at
        try:
            if k == 1:
                res = plan.solve(batch[0].b, atol=atol, rtol=rtol)
                size = 1
            else:
                B = jnp.stack([r.b for r in batch])
                pad = self.server.max_batch - k
                if pad > 0:  # pad into the ONE compiled bucket program
                    B = jnp.concatenate(
                        [B, jnp.zeros((pad, B.shape[1]), B.dtype)]
                    )
                size = B.shape[0]
                record_bucket(k, size)
                res = plan.solve_batched(B, atol=atol, rtol=rtol)
            import jax

            jax.block_until_ready(res.x)
        except BaseException as e:
            for r in batch:
                r.future.set_exception(e)
            _metrics.counter("serve.solve_errors").inc(k)
            return
        solve_s = time.monotonic() - t0
        _metrics.histogram("serve.bucket_solve_s").record(solve_s)

        if k == 1:
            iters = np.asarray([iterations_from_history(res.history)])
            xs = [res.x]
            conv = [bool(res.converged)]
            rnorm = [float(res.residual_norm)]
        else:
            iters = np.asarray(iterations_from_history(res.history))[:k]
            _metrics.counter("serve.wasted_lane_iterations").inc(
                bucket_waste(iters, size)
            )
            xs = [res.x[i] for i in range(k)]
            conv = [bool(c) for c in np.asarray(res.converged)[:k]]
            rnorm = [float(v) for v in np.asarray(res.residual_norm)[:k]]
        for i, r in enumerate(batch):
            it = int(iters[i])
            _metrics.histogram("serve.rhs_iterations").record(it)
            r.future.set_result(ServeResult(
                x=xs[i], iterations=it, converged=conv[i],
                residual_norm=rnorm[i],
                queue_wait_s=max(t0 - r.enqueued_at, 0.0),
                solve_s=solve_s, bucket_size=k,
                bucket_occupancy=k / size,
            ))


class SolverServer:
    """Async multi-plan solver serving (module docstring; docs/serving.md).

    ``max_batch``/``max_wait_ms`` set the bucket-closing policy,
    ``max_depth`` the per-plan admission bound (beyond it ``submit``
    raises ``QueueFull`` — explicit backpressure), ``max_plans`` the
    warm-plan pool size. The remaining kwargs are per-request defaults;
    ``submit(..., method=..., engine=...)`` overrides route to their own
    pooled plan.
    """

    def __init__(self, *, max_batch: int = 8, max_wait_ms: float = 2.0,
                 max_depth: int = 256, max_plans: int = 8,
                 method: str = "pipecg", engine: str = "auto", M="jacobi",
                 atol: float = 1e-5, rtol: float = 0.0, maxiter: int = 10000,
                 **plan_kwargs):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.max_depth = int(max_depth)
        self.defaults = dict(method=method, engine=engine, M=M, atol=atol,
                             rtol=rtol, maxiter=maxiter, **plan_kwargs)
        self.pool = PlanPool(max_plans=max_plans, on_evict=self._on_evict)
        self._workers: Dict[tuple, _PlanWorker] = {}
        self._lock = threading.Lock()
        self._closed = False

    # -- admission --------------------------------------------------------

    def submit(self, A, b, *, atol: Optional[float] = None,
               rtol: Optional[float] = None,
               deadline_ms: Optional[float] = None, **overrides) -> Future:
        """Admit one rhs; returns a Future resolving to a ServeResult.

        Non-blocking: a warm plan's bucket forms around the request; a
        cold (method/engine/tolerance-bucket) miss starts an async build
        that never stalls traffic on warm plans. Raises ``QueueFull`` /
        ``ServerClosed`` for explicit backpressure.
        """
        if self._closed:
            reject("shutdown")
            raise ServerClosed("SolverServer is shut down")
        cfg = dict(self.defaults)
        cfg.update(overrides)
        if atol is not None:
            cfg["atol"] = float(atol)
        if rtol is not None:
            cfg["rtol"] = float(rtol)
        entry, _ = self.pool.get_or_create(A, cfg)
        worker = self._worker_for(entry)
        req = SolveRequest(
            b=b, atol=float(cfg["atol"]), rtol=float(cfg["rtol"]),
            deadline=None if deadline_ms is None
            else time.monotonic() + deadline_ms / 1e3,
        )
        _metrics.counter("serve.requests").inc()
        worker.queue.put(req)
        return req.future

    def submit_many(self, A, B: Sequence, **kwargs) -> List[Future]:
        """Admit a batch of rhs (one Future each, same routing)."""
        return [self.submit(A, b, **kwargs) for b in B]

    # -- workers / lifecycle ----------------------------------------------

    def _worker_for(self, entry: PlanEntry) -> _PlanWorker:
        with self._lock:
            worker = self._workers.get(entry.key)
            if worker is None or worker.entry is not entry:
                worker = self._workers[entry.key] = _PlanWorker(self, entry)
            return worker

    def _on_evict(self, entry: PlanEntry) -> None:
        # evicted plans drain gracefully: queue stops admitting, the
        # worker serves what is queued (it holds the plan ref), then exits
        with self._lock:
            worker = self._workers.pop(entry.key, None)
        if worker is not None:
            worker.queue.close()

    def plans(self) -> List:
        """The pool's built plans (building/failed entries excluded)."""
        return [e.plan for e in self.pool.entries() if e.plan is not None]

    def entries(self):
        return self.pool.entries()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every queue is empty and every worker idle."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._lock:
                workers = list(self._workers.values())
            busy = [w for w in workers
                    if len(w.queue) or not w.idle.is_set()
                    or (not w.entry.ready.is_set())]
            if not busy:
                return True
            if deadline is not None and time.monotonic() > deadline:
                return False
            time.sleep(0.002)

    def shutdown(self, drain: bool = True, timeout: Optional[float] = None) -> None:
        """Stop admitting; with ``drain`` serve everything queued first.

        Graceful shutdown drops zero requests: queues close (late
        ``submit`` raises and is counted under ``serve.rejects.shutdown``)
        while workers finish every admitted bucket, then threads join.
        """
        self._closed = True
        with self._lock:
            workers = list(self._workers.values())
        for w in workers:
            if not drain:
                w.queue.fail_all(ServerClosed("server shut down without drain"))
            w.queue.close()
        for w in workers:
            w.thread.join(timeout)

    def __enter__(self) -> "SolverServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=True)

    # -- warm start --------------------------------------------------------

    def save_manifest(self, path: str, *, operator_specs=None) -> dict:
        """Snapshot this server's built plans for cross-process warm start."""
        from .warmstart import save_manifest

        return save_manifest(
            path, self.plans(), operator_specs=operator_specs,
            serve={"max_batch": self.max_batch,
                   "max_wait_ms": self.max_wait_ms,
                   "max_depth": self.max_depth},
        )

    @classmethod
    def from_manifest(cls, path: str, *, warm: bool = True,
                      strict: bool = True, **overrides) -> "SolverServer":
        """Build a server with every manifest plan rebuilt + re-traced.

        After this returns (``warm=True``), the first request against any
        manifest plan re-traces nothing — the replica is hot before it
        sees traffic.
        """
        from .warmstart import load_manifest

        loaded, serve_cfg = load_manifest(path, warm=False, strict=strict)
        kwargs = {"max_batch": serve_cfg.get("max_batch", 8),
                  "max_wait_ms": serve_cfg.get("max_wait_ms", 2.0),
                  "max_depth": serve_cfg.get("max_depth", 256)}
        kwargs.update(overrides)
        srv = cls(**kwargs)
        import jax.numpy as jnp

        for p, _entry in loaded:
            srv.pool.adopt(p.A, p)
            if warm:
                n = p.A.shape[0]
                zeros = jnp.zeros((n,), p.A.dtype)
                p.solve(zeros)  # the single-rhs program
                if srv.max_batch > 1:  # the bucket program at serving size
                    p.solve_batched(jnp.zeros((srv.max_batch, n), p.A.dtype))
        return srv

    # -- introspection -----------------------------------------------------

    def stats(self) -> dict:
        """Pool/queue/worker state (metrics live in ``repro.obs``)."""
        with self._lock:
            queues = {str(k): len(w.queue) for k, w in self._workers.items()}
        return {
            "plans": len(self.pool),
            "workers": len(queues),
            "queue_depths": queues,
            "trace_counts": {p.method: p.trace_count for p in self.plans()},
            "closed": self._closed,
        }
