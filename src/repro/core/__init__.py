"""Core solver library — the paper's contribution as composable JAX modules.

Single-device solvers:
  pcg             — Algorithm 1 (baseline; 3 blocking reductions/iter)
  chronopoulos_cg — single merged reduction/iter, not overlapped
  pipecg          — Algorithm 2 (reduction overlapped with PC+SPMV);
                    engine="pallas" uses the fused iteration-core kernel

Distributed (shard_map): repro.core.distributed.pipecg_distributed with
methods "h1"/"h2"/"h3" mirroring the paper's Hybrid-PIPECG-1/2/3.
"""
from .chronopoulos import chronopoulos_cg
from .pcg import dot_f32, pcg
from .pipecg import pipecg
from .preconditioners import (
    BlockJacobiPC,
    IdentityPC,
    JacobiPC,
    apply_pc,
    block_jacobi,
    identity,
    jacobi,
)
from .types import SolveResult

__all__ = [
    "BlockJacobiPC",
    "IdentityPC",
    "JacobiPC",
    "SolveResult",
    "apply_pc",
    "block_jacobi",
    "chronopoulos_cg",
    "dot_f32",
    "identity",
    "jacobi",
    "pcg",
    "pipecg",
]
