"""Core solver library — one iteration core, many execution strategies.

The paper's contribution is that *one* PIPECG recurrence admits many
execution strategies; the package is layered accordingly:

``iteration``   The single canonical PIPECG iteration core
                (``pipecg_vma_core``: 8 VMAs + PC + dot partials — the only
                implementation of the recurrence in the repo) and the
                shared solver loop ``run_pipecg``, generic over the three
                strategy axes below.
``reduce``      Reduction strategies for the dot partials: ``local`` /
                ``separate`` psums (h1) / ``packed`` psum (h2/h3).
                Extension point: ``register_reducer``.
``sparse.spmv`` SPMV engine dispatch (dense / DIA / BELL x jnp / Pallas).
                Extension point: ``register_spmv``.
``distributed`` shard_map wrapper: distributed SPMV strategies
                (all-gather, halo-ppermute) + method registry h1/h2/h3.
                Extension point: ``register_method``.
``iteration``   also hosts the iteration-core engine registry
                ("jnp" / "pallas" fused kernel). Extension point:
                ``register_core``.

Front-ends (thin configuration over the shared loop):

  pcg             — Algorithm 1 (baseline; 3 blocking reductions/iter)
  chronopoulos_cg — single merged reduction/iter, not overlapped
  pipecg          — Algorithm 2 single-device (engine="pallas" fuses the
                    iteration core; spmv_engine routes the SPMV kernels)
  distributed.pipecg_distributed — h1..h4 / pl2 / pl3 on a device mesh
                    (pl2/pl3 swap in the depth-l loop from
                    ``make_deep_pipecg_core``; matrix in docs/distributed.md)

The top-level plan/execute API (``repro.plan`` -> reusable ``SolverPlan``,
plus one-shot ``repro.solve`` over a keyed plan cache; see ``repro.plan``)
unifies all of them and amortizes their setup across right-hand sides.
"""
from .chronopoulos import chronopoulos_cg
from .iteration import dot_f32, get_core, pipecg_vma_core, register_core, run_pipecg
from .pcg import pcg
from .pipecg import pipecg
from .preconditioners import (
    BlockJacobiPC,
    IdentityPC,
    JacobiPC,
    apply_pc,
    block_jacobi,
    identity,
    jacobi,
)
from .reduce import make_reducer, register_reducer
from .types import SolveResult

__all__ = [
    "BlockJacobiPC",
    "IdentityPC",
    "JacobiPC",
    "SolveResult",
    "apply_pc",
    "block_jacobi",
    "chronopoulos_cg",
    "dot_f32",
    "get_core",
    "identity",
    "jacobi",
    "make_reducer",
    "pcg",
    "pipecg",
    "pipecg_vma_core",
    "register_core",
    "register_reducer",
    "run_pipecg",
]
