"""Distributed PIPECG over a TPU mesh — the paper's three hybrid methods.

The paper's CPU+GPU task/data split is re-targeted to inter-chip
parallelism (DESIGN.md §2). Rows of the banded operator are partitioned
across the ``rows`` mesh axis; each method changes *what* is communicated
per iteration and *what hides it*:

method "h1" (Hybrid-PIPECG-1 analogue)
    Three separate ``psum`` reductions (gamma, delta, ||u||^2) issued right
    after the vector updates, plus a full ``all_gather`` of the m vector for
    the SPMV. Maximum collective count; every collective is dataflow-
    independent of PC+SPMV, so an async scheduler may overlap them.

method "h2" (Hybrid-PIPECG-2 analogue)
    The three dot partials are packed into ONE length-3 ``psum`` — the
    paper's copy-shrinking trick (3N -> N) applied to reduction latency
    (3 collectives -> 1). SPMV still consumes a full ``all_gather``.

method "h3" (Hybrid-PIPECG-3 analogue)
    Packed psum + 2-D decomposition: the SPMV splits into a local band part
    (needs only resident x — the paper's nnz1) and boundary corrections
    (the paper's nnz2) fed by a ring ``ppermute`` of bandwidth-sized halo
    slabs. The halo exchange is dataflow-independent of SPMV part 1, which
    is exactly the overlap the paper engineers with CUDA streams. Supports
    performance-model (nnz/throughput-weighted) partitions with unequal
    shard sizes.

All three run inside one ``shard_map``-ped ``lax.while_loop``; convergence
scalars are replicated via the psums.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..sparse.partition import ShardedDIA
from .pcg import dot_f32
from .types import SolveResult

__all__ = ["pipecg_distributed", "make_solver_mesh", "spmv_halo", "spmv_allgather"]


def make_solver_mesh(n_shards: int, axis: str = "rows") -> Mesh:
    """1-D mesh over the first n_shards devices."""
    devs = np.array(jax.devices()[:n_shards])
    return Mesh(devs, (axis,))


# ---------------------------------------------------------------------------
# distributed SPMV variants (called inside shard_map)
# ---------------------------------------------------------------------------

def spmv_allgather(data, x, rows, offsets: Tuple[int, ...], hw: int, axis: str):
    """Full-vector SPMV: all_gather m, then band-multiply my row block.

    Requires equal shard sizes (rows == R on every shard). This is the
    h1/h2 communication pattern: N elements over the interconnect per
    SPMV, like the paper's full-vector PCIe copies.
    """
    R = x.shape[0]
    xfull = jax.lax.all_gather(x, axis)  # (P, R)
    Pn = xfull.shape[0]
    flat = xfull.reshape(Pn * R)
    flat = jnp.concatenate([jnp.zeros((hw,), x.dtype), flat, jnp.zeros((hw,), x.dtype)])
    p = jax.lax.axis_index(axis)
    y = jnp.zeros((R,), x.dtype)
    for j, o in enumerate(offsets):
        seg = jax.lax.dynamic_slice(flat, (hw + p * R + o,), (R,))
        y = y + data[j] * seg
    del rows  # equal shards: validity handled by zero data/x padding
    return y


def spmv_halo(data, x, rows, offsets: Tuple[int, ...], hw: int, axis: str, n_shards: int):
    """2-D decomposed SPMV: local band (nnz1) + halo corrections (nnz2).

    Only two bandwidth-sized slabs cross the interconnect (ring ppermute);
    SPMV part 1 has no data dependency on them — the overlap surface.
    Supports unequal (performance-model) shard sizes via ``rows``.
    """
    R = x.shape[0]
    # --- issue halo exchange (independent of part 1) ---
    head = x[:hw]  # my first hw valid rows -> left neighbor's right halo
    tail = jax.lax.dynamic_slice(x, (rows - hw,), (hw,))  # my last hw valid rows
    right_halo = jax.lax.ppermute(head, axis, [(p, p - 1) for p in range(1, n_shards)])
    left_halo = jax.lax.ppermute(tail, axis, [(p, p + 1) for p in range(n_shards - 1)])

    # --- SPMV part 1: local columns only (paper's nnz1) ---
    y = jnp.zeros((R,), x.dtype)
    for j, o in enumerate(offsets):
        if o == 0:
            y = y + data[j] * x
        elif o > 0:
            seg = jnp.concatenate([x[o:], jnp.zeros((o,), x.dtype)])
            y = y + data[j] * seg
        else:
            seg = jnp.concatenate([jnp.zeros((-o,), x.dtype), x[:o]])
            y = y + data[j] * seg

    # --- SPMV part 2: boundary corrections (paper's nnz2) ---
    for j, o in enumerate(offsets):
        if o > 0:
            # rows [rows-o, rows) read the right neighbor's first o entries
            dslab = jax.lax.dynamic_slice(data[j], (rows - o,), (o,))
            yslab = jax.lax.dynamic_slice(y, (rows - o,), (o,))
            y = jax.lax.dynamic_update_slice(y, yslab + dslab * right_halo[:o], (rows - o,))
        elif o < 0:
            # rows [0, -o) read the left neighbor's last -o entries
            y = y.at[: -o].add(data[j][: -o] * left_halo[hw + o :])
    return y


# ---------------------------------------------------------------------------
# the distributed solver
# ---------------------------------------------------------------------------

def _local_vma_core(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta):
    """PIPECG lines 10-21 on the local block (same math as single-device)."""
    z = n + beta * z
    q = m + beta * q
    s = w + beta * s
    p = u + beta * p
    x = x + alpha * p
    r = r - alpha * s
    u = u - alpha * q
    w = w - alpha * z
    m = inv_diag * w
    g_part = dot_f32(r, u)
    d_part = dot_f32(w, u)
    n_part = dot_f32(u, u)
    return z, q, s, p, x, r, u, w, m, g_part, d_part, n_part


def pipecg_distributed(
    As: ShardedDIA,
    b_sh: jax.Array,
    inv_diag_sh: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "rows",
    method: str = "h3",
    atol: float = 1e-5,
    rtol: float = 0.0,
    maxiter: int = 10000,
) -> SolveResult:
    """Distributed PIPECG on row-sharded banded A.

    As          — ShardedDIA from repro.sparse.shard_dia (h3 may use
                  performance-model/unequal partitions; h1/h2 require equal).
    b_sh        — (P, R) sharded rhs from shard_vector.
    inv_diag_sh — (P, R) sharded Jacobi inverse diagonal (use ones for no PC).
    Returns SolveResult with x of shape (P*R,) padded; use unshard_vector.
    """
    if method not in ("h1", "h2", "h3"):
        raise ValueError(f"method must be h1|h2|h3, got {method}")
    Pn = As.n_shards
    R = As.rows_max
    hw = As.bandwidth
    offsets = As.offsets
    sizes = np.diff(np.asarray(As.boundaries))
    if method in ("h1", "h2") and (sizes != R).any():
        raise ValueError(f"{method} requires equal shards (use balanced_rows); sizes={sizes}")

    if method == "h3":
        local_spmv = partial(spmv_halo, offsets=offsets, hw=hw, axis=axis, n_shards=Pn)
    else:
        local_spmv = partial(spmv_allgather, offsets=offsets, hw=hw, axis=axis)

    def psum_dots(g, d, nn):
        if method == "h1":
            # three separate reductions (paper: three separate async copies)
            return (
                jax.lax.psum(g, axis),
                jax.lax.psum(d, axis),
                jax.lax.psum(nn, axis),
            )
        packed = jax.lax.psum(jnp.stack([g, d, nn]), axis)
        return packed[0], packed[1], packed[2]

    spec_mat = P(axis, None, None)
    spec_vec = P(axis, None)
    spec_scalar = P(axis)

    @partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(spec_mat, spec_scalar, spec_vec, spec_vec),
        out_specs=(P(axis, None), P(), P(), P(), P()),
    )
    def _solve(data_blk, rows_blk, b_blk, inv_blk):
        data = data_blk[0]  # (k, R)
        rows = rows_blk[0]
        b = b_blk[0]  # (R,)
        inv_diag = inv_blk[0]
        dtype = b.dtype

        def dist_spmv(v):
            return local_spmv(data, v, rows)

        # init (Alg 2 lines 1-3), x0 = 0
        x0 = jnp.zeros_like(b)
        r0 = b
        u0 = inv_diag * r0
        w0 = dist_spmv(u0)
        g, d, nn = psum_dots(dot_f32(r0, u0), dot_f32(w0, u0), dot_f32(u0, u0))
        norm0 = jnp.sqrt(nn)
        m0 = inv_diag * w0
        n0 = dist_spmv(m0)
        thresh = jnp.maximum(jnp.float32(atol), jnp.float32(rtol) * norm0)
        hist0 = jnp.full((maxiter + 1,), jnp.nan, jnp.float32).at[0].set(norm0.astype(jnp.float32))
        zv = jnp.zeros_like(b)

        def cond(state):
            return (state[0] < maxiter) & (state[-2] > thresh)

        def body(state):
            (i, x, r, u, w, z, q, s, p, m, n,
             gamma, gamma_prev, delta, alpha_prev, norm, hist) = state
            beta = jnp.where(i > 0, gamma / gamma_prev, 0.0)
            alpha = jnp.where(i > 0, gamma / (delta - beta * gamma / alpha_prev), gamma / delta)
            z, q, s, p, x, r, u, w, m, g_p, d_p, n_p = _local_vma_core(
                z, q, s, p, x, r, u, w, n, m, inv_diag, alpha.astype(dtype), beta.astype(dtype)
            )
            # the reduction(s): results consumed next iteration only
            gamma_new, delta_new, uu = psum_dots(g_p, d_p, n_p)
            # PC already fused into the VMA core; SPMV is reduction-independent
            n = dist_spmv(m)
            norm_new = jnp.sqrt(uu)
            hist = hist.at[i + 1].set(norm_new.astype(jnp.float32))
            return (i + 1, x, r, u, w, z, q, s, p, m, n,
                    gamma_new, gamma, delta_new, alpha, norm_new, hist)

        acc = g.dtype
        state = (
            jnp.int32(0), x0, r0, u0, w0, zv, zv, zv, zv, m0, n0,
            g, jnp.ones((), acc), d, jnp.ones((), acc), norm0, hist0,
        )
        out = jax.lax.while_loop(cond, body, state)
        i, x, norm, hist = out[0], out[1], out[-2], out[-1]
        return x[None], i, norm, norm <= thresh, hist

    x, iters, norm, conv, hist = _solve(As.data, As.rows_valid, b_sh, inv_diag_sh)
    return SolveResult(
        x=x.reshape(Pn, R), iterations=iters, residual_norm=norm, converged=conv, history=hist
    )
