"""Distributed PIPECG over a TPU mesh — the paper's hybrid methods, plus
communication-reduced deep pipelines and hierarchical reductions.

The paper's CPU+GPU task/data split is re-targeted to inter-chip
parallelism (DESIGN.md §2). Rows of the banded operator are partitioned
across the mesh; each method is pure *configuration* of a shared solver
loop — a reduction strategy (``core.reduce``), a distributed SPMV
strategy, and a pipeline depth (``core.iteration``):

    method   reduction           SPMV         depth  (analogue)
    ------   -----------------   ----------   -----  ------------------------
    "h1"     3 separate psums    all_gather   1      Hybrid-PIPECG-1
    "h2"     1 packed psum       all_gather   1      Hybrid-PIPECG-2
    "h3"     1 packed psum       halo         1      Hybrid-PIPECG-3 (2-D)
    "h4"     hierarchical 2-st.  halo         1      intra-pod + inter-pod
    "pl2"    1 packed Gram psum  halo         2      deep pipeline, 1 red/2 it
    "pl3"    1 packed Gram psum  halo         3      deep pipeline, 1 red/3 it

See ``docs/distributed.md`` for the full selection matrix
(reductions/iteration, when to use which, residual-replacement guidance).

SPMV strategies:

``allgather`` — full-vector SPMV (N elements over the interconnect per
    SPMV, like the paper's full-vector PCIe copies); equal shards only.
``halo`` — local band part (paper's nnz1, needs only resident x) plus
    boundary corrections (nnz2) fed by ring ``ppermute``s of
    bandwidth-sized slabs. The halo exchange is dataflow-independent of
    SPMV part 1 — exactly the overlap the paper engineers with CUDA
    streams. Supports performance-model (unequal) partitions, and —
    for equal shards — *multi-hop* halos when the band is wider than a
    shard (tiny shards on big stencils): ``ceil(bandwidth/rows)`` ring
    shifts build the halo from as many neighbors as the band reaches.

Reduction strategies come from ``core.reduce`` (``separate``/``packed``/
``h4`` hierarchical); the ``reducer=``/``spmv=`` overrides recombine any
method with any strategy. The hierarchical reducer needs a 2-D
``(pod, sub)`` mesh — build one with ``make_solver_mesh(n, sub=...)``.

All methods run a canonical loop inside one ``shard_map``-ped
``lax.while_loop``: ``run_pipecg`` for depth-1 methods, the depth-l
coordinate loop from ``make_deep_pipecg_core`` for ``pl2``/``pl3``
(jaxpr census: ONE global reduction per *l* iterations). Residual
replacement (``replace_every``) threads through every method. With
``nrhs=k`` the whole k-rhs batch runs as ONE program — the solver loop
is ``vmap``-ed *inside* the shard_map block, so every global reduction
carries k systems' partials at once (k-fold useful work per reduction,
zero Python-level per-rhs loops). New methods = new registry entries.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..obs.trace import trace_scope
from ..sparse.partition import ShardedDIA
from .iteration import get_core, make_deep_pipecg_core, run_pipecg
from .reduce import make_reducer, reducer_needs_subaxis
from .types import SolveResult

__all__ = [
    "pipecg_distributed",
    "build_distributed_solver",
    "make_solver_mesh",
    "spmv_halo",
    "spmv_allgather",
    "DistMethod",
    "get_method",
    "register_dist_spmv",
    "register_method",
    "method_names",
]


def make_solver_mesh(n_shards: int, axis: str = "rows", sub: Optional[int] = None) -> Mesh:
    """Mesh over the first n_shards devices.

    ``sub=None`` — 1-D mesh ``(axis,)``. ``sub=k`` — 2-D hierarchical
    mesh ``("pod", axis)`` of shape ``(n_shards // k, k)``: ``k`` devices
    per pod, linear device order preserved (pod-major), as the
    hierarchical "h4" reducer requires. Row sharding then runs over the
    flattened ``("pod", axis)`` axes, so every SPMV strategy keeps its
    linear ring/gather order.
    """
    devs = np.array(jax.devices()[:n_shards])
    if sub is None:
        return Mesh(devs, (axis,))
    if sub < 1 or n_shards % sub:
        raise ValueError(
            f"sub-axis size {sub} must divide the shard count {n_shards} "
            f"(pods of equal size)"
        )
    return Mesh(devs.reshape(n_shards // sub, sub), ("pod", axis))


# ---------------------------------------------------------------------------
# distributed SPMV strategies (called inside shard_map)
# ---------------------------------------------------------------------------
#
# Uniform signature:
#   fn(data, x, rows, *, offsets, hw, axis, n_shards, hops) -> y_local
# ``axis`` is a mesh-axis name or tuple of names (2-D hierarchical mesh);
# linear shard order is the flattened axis order either way. ``hops`` is
# the static halo reach in whole shards (ceil(hw / rows)) when shards are
# equal-sized, or None for the dynamic unequal-shard path.


def spmv_allgather(data, x, rows, offsets: Tuple[int, ...], hw: int, axis, n_shards: int = 0,
                   hops: Optional[int] = 1):
    """Full-vector SPMV: all_gather m, then band-multiply my row block.

    Requires equal shard sizes (rows == R on every shard). This is the
    h1/h2 communication pattern: N elements over the interconnect per
    SPMV, like the paper's full-vector PCIe copies. ``n_shards``/``hops``
    are part of the uniform strategy signature but unused (all_gather
    discovers the mesh, and a full gather has no hop structure). Band
    width may exceed the shard size — the gathered vector covers any
    offset.
    """
    R = x.shape[0]
    xfull = jax.lax.all_gather(x, axis)  # (..., R): leading mesh axes
    flat = xfull.reshape(-1)
    flat = jnp.concatenate([jnp.zeros((hw,), x.dtype), flat, jnp.zeros((hw,), x.dtype)])
    p = jax.lax.axis_index(axis)  # linear index, also for tuple axes
    y = jnp.zeros((R,), x.dtype)
    for j, o in enumerate(offsets):
        seg = jax.lax.dynamic_slice(flat, (hw + p * R + o,), (R,))
        y = y + data[j] * seg
    del rows  # equal shards: validity handled by zero data/x padding
    return y


def _shift_segment(x, o: int):
    """x shifted by offset o with zero fill — valid for any |o| (>= R too)."""
    R = x.shape[0]
    if o == 0:
        return x
    if o > 0:
        return jnp.concatenate([x[o:], jnp.zeros((min(o, R),), x.dtype)])
    return jnp.concatenate([jnp.zeros((min(-o, R),), x.dtype), x[:o] if -o < R else x[:0]])


def spmv_halo(data, x, rows, offsets: Tuple[int, ...], hw: int, axis, n_shards: int,
              hops: Optional[int] = 1):
    """2-D decomposed SPMV: local band (nnz1) + halo corrections (nnz2).

    Only boundary slabs cross the interconnect (ring ppermute); SPMV
    part 1 has no data dependency on them — the overlap surface.

    Two paths, chosen statically at build time:

    * ``hops=None`` — unequal (performance-model) shard sizes, halo width
      ``hw`` <= smallest shard: one dynamic-sliced slab per direction
      from the ring neighbors (the original h3 exchange).
    * ``hops=k`` (equal shards) — static path supporting ``hw`` larger
      than a shard: ``k = ceil(hw / R)`` whole-block ring shifts per
      direction assemble a ``k*R``-wide halo buffer, so a band that spans
      several shards reads every neighbor it touches (multi-hop). For
      ``k=1`` this degenerates to the classic single-slab exchange with
      static slices. Edge shards receive zero-filled halos (ppermute
      semantics), matching the DIA zero-outside-band convention.
    """
    R = x.shape[0]
    if hops is not None:
        # ---- equal shards: static (possibly multi-hop) halo path ----
        if hops * R < hw:
            raise ValueError(f"hops={hops} x rows={R} cannot cover bandwidth {hw}")
        # issue all halo shifts first (independent of part 1)
        right_blocks = [
            jax.lax.ppermute(x, axis, [(p, p - k) for p in range(k, n_shards)])
            for k in range(1, hops + 1)
        ]  # blocks of shards p+1 .. p+hops, in order
        left_blocks = [
            jax.lax.ppermute(x, axis, [(p, p + k) for p in range(n_shards - k)])
            for k in range(hops, 0, -1)
        ]  # blocks of shards p-hops .. p-1, in order
        right_buf = jnp.concatenate(right_blocks) if right_blocks else x[:0]
        left_buf = jnp.concatenate(left_blocks) if left_blocks else x[:0]
        L = hops * R

        # --- SPMV part 1: local columns only (paper's nnz1) ---
        y = jnp.zeros((R,), x.dtype)
        for j, o in enumerate(offsets):
            y = y + data[j] * _shift_segment(x, o)

        # --- SPMV part 2: boundary corrections (paper's nnz2) ---
        for j, o in enumerate(offsets):
            if o > 0:
                # rows [max(R-o,0), R) read the right halo buffer
                start = max(R - o, 0)
                w = R - start
                y = y.at[start:].add(
                    data[j][start:] * jax.lax.slice(right_buf, (o - R + start,),
                                                    (o - R + start + w,))
                )
            elif o < 0:
                # rows [0, min(-o,R)) read the left halo buffer
                w = min(-o, R)
                y = y.at[:w].add(
                    data[j][:w] * jax.lax.slice(left_buf, (L + o,), (L + o + w,))
                )
        return y

    # ---- unequal shards: dynamic single-hop path (hw <= min shard) ----
    # --- issue halo exchange (independent of part 1) ---
    head = x[:hw]  # my first hw valid rows -> left neighbor's right halo
    tail = jax.lax.dynamic_slice(x, (rows - hw,), (hw,))  # my last hw valid rows
    right_halo = jax.lax.ppermute(head, axis, [(p, p - 1) for p in range(1, n_shards)])
    left_halo = jax.lax.ppermute(tail, axis, [(p, p + 1) for p in range(n_shards - 1)])

    # --- SPMV part 1: local columns only (paper's nnz1) ---
    y = jnp.zeros((R,), x.dtype)
    for j, o in enumerate(offsets):
        y = y + data[j] * _shift_segment(x, o)

    # --- SPMV part 2: boundary corrections (paper's nnz2) ---
    for j, o in enumerate(offsets):
        if o > 0:
            # rows [rows-o, rows) read the right neighbor's first o entries
            dslab = jax.lax.dynamic_slice(data[j], (rows - o,), (o,))
            yslab = jax.lax.dynamic_slice(y, (rows - o,), (o,))
            y = jax.lax.dynamic_update_slice(y, yslab + dslab * right_halo[:o], (rows - o,))
        elif o < 0:
            # rows [0, -o) read the left neighbor's last -o entries
            y = y.at[: -o].add(data[j][: -o] * left_halo[hw + o :])
    return y


_DIST_SPMV = {"allgather": spmv_allgather, "halo": spmv_halo}
# strategies that index the gathered vector by p*R: all shards one size
_EQUAL_ONLY_SPMV = {"allgather"}


def register_dist_spmv(name: str, fn, *, overwrite: bool = False,
                       equal_shards_only: bool = False) -> None:
    """Register a distributed SPMV strategy (uniform signature above).

    Raises ValueError if ``name`` is already registered, unless
    ``overwrite=True`` — silent replacement hides plug-in clashes.
    """
    if name in _DIST_SPMV and not overwrite:
        raise ValueError(
            f"distributed SPMV strategy {name!r} already registered; pass "
            f"overwrite=True to replace it"
        )
    _DIST_SPMV[name] = fn
    if equal_shards_only:
        _EQUAL_ONLY_SPMV.add(name)


# ---------------------------------------------------------------------------
# methods = (reduction, SPMV, pipeline depth) configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DistMethod:
    """A distributed execution strategy for the shared solver loops.

    ``pipeline_depth`` selects the loop: 1 = PIPECG (``run_pipecg``,
    one reduction per iteration, overlapped with one SPMV); l >= 2 = the
    depth-l communication-reduced loop (``make_deep_pipecg_core``, ONE
    packed Gram reduction per l iterations).
    """

    reduce: str  # core.reduce strategy name
    spmv: str  # key into _DIST_SPMV
    equal_shards_only: bool  # allgather indexes by p*R: all shards same size
    pipeline_depth: int = 1  # iterations amortized per global reduction


_METHODS = {
    "h1": DistMethod(reduce="separate", spmv="allgather", equal_shards_only=True),
    "h2": DistMethod(reduce="packed", spmv="allgather", equal_shards_only=True),
    "h3": DistMethod(reduce="packed", spmv="halo", equal_shards_only=False),
    "h4": DistMethod(reduce="h4", spmv="halo", equal_shards_only=False),
    "pl2": DistMethod(reduce="packed", spmv="halo", equal_shards_only=False,
                      pipeline_depth=2),
    "pl3": DistMethod(reduce="packed", spmv="halo", equal_shards_only=False,
                      pipeline_depth=3),
}


def register_method(name: str, method: DistMethod, *, overwrite: bool = False) -> None:
    """Register a new (reducer, spmv, depth) combination as a named method.

    Raises ValueError if ``name`` is already registered, unless
    ``overwrite=True`` — silent replacement hides plug-in clashes.
    """
    from .reduce import reducer_names

    if name in _METHODS and not overwrite:
        raise ValueError(
            f"distributed method {name!r} already registered; pass "
            f"overwrite=True to replace it"
        )
    if method.spmv not in _DIST_SPMV:
        raise ValueError(
            f"unknown SPMV strategy {method.spmv!r}; register it first via "
            f"register_dist_spmv (have {tuple(sorted(_DIST_SPMV))})"
        )
    if method.reduce not in reducer_names():
        raise ValueError(
            f"unknown reduction strategy {method.reduce!r}; register it first "
            f"via core.reduce.register_reducer (have {reducer_names()})"
        )
    if method.pipeline_depth < 1:
        raise ValueError(f"pipeline_depth must be >= 1, got {method.pipeline_depth}")
    _METHODS[name] = method


def method_names() -> Tuple[str, ...]:
    return tuple(sorted(_METHODS))


def get_method(name: str) -> DistMethod:
    """Look up a registered distributed method (for introspection/plans)."""
    if name not in _METHODS:
        raise ValueError(f"method must be one of {method_names()}, got {name}")
    return _METHODS[name]


# ---------------------------------------------------------------------------
# the distributed solver: shard_map around the shared loop
# ---------------------------------------------------------------------------

def build_distributed_solver(
    As: ShardedDIA,
    *,
    mesh: Mesh,
    axis: str = "rows",
    method: str = "h3",
    engine: str = "jnp",
    maxiter: int = 10000,
    reducer: Optional[str] = None,
    spmv: Optional[str] = None,
    replace_every: int = 0,
    nrhs: Optional[int] = None,
):
    """Build (once) the shard_map'd solver program for one sharded operator.

    This is the setup half of the plan/execute split: validation, strategy
    lookup and the ``shard_map`` closure happen here; the returned
    ``runner(b_sh, inv_diag_sh, atol, rtol) -> SolveResult`` only executes.
    ``atol``/``rtol`` are traced arguments, so one built runner serves any
    tolerance without recompilation; callers (``repro.plan``) wrap the
    runner in a single pinned ``jax.jit``.

    ``reducer``/``spmv`` override the method's registered strategies (any
    method x reducer x spmv recombination); ``replace_every`` threads the
    full-precision residual-replacement safety net through every method —
    recommended (e.g. 50) for the deep pipelines ``pl2``/``pl3``.

    ``nrhs=k`` builds the mesh-level *batched* program: ``b_sh`` then
    carries a rhs axis — shape (P, k, R) — and the solver loop runs
    ``vmap``-ed inside the shard_map block, ONE program for the whole
    batch whose every global reduction carries k systems' partials.
    Returned ``x`` is (P, k, R); the other fields gain a leading k.
    """
    cfg = get_method(method)
    depth = cfg.pipeline_depth
    reduce_name = cfg.reduce if reducer is None else reducer
    spmv_name = cfg.spmv if spmv is None else spmv
    if spmv_name not in _DIST_SPMV:
        raise ValueError(
            f"unknown SPMV strategy {spmv_name!r}; have {tuple(sorted(_DIST_SPMV))}"
        )
    Pn = As.n_shards
    R = As.rows_max
    hw = As.bandwidth
    offsets = As.offsets
    sizes = np.diff(np.asarray(As.boundaries))
    equal = bool((sizes == R).all())
    if (cfg.equal_shards_only or spmv_name in _EQUAL_ONLY_SPMV) and not equal:
        raise ValueError(f"{method} requires equal shards (use balanced_rows); sizes={sizes}")

    axis_names = tuple(mesh.axis_names)
    if int(np.prod(mesh.devices.shape)) != Pn:
        raise ValueError(
            f"mesh has {int(np.prod(mesh.devices.shape))} devices but the "
            f"operator is sharded {Pn} ways"
        )
    # 1-D mesh -> plain axis name; 2-D hierarchical mesh -> the axis tuple
    # (psum/all_gather/ppermute/axis_index all accept tuples; linear shard
    # order is the flattened axis order)
    ax = axis_names[0] if len(axis_names) == 1 else axis_names
    if reducer_needs_subaxis(reduce_name) and len(axis_names) < 2:
        raise ValueError(
            f"reducer {reduce_name!r} is hierarchical and needs a 2-D (pod, sub) "
            f"mesh; build one with make_solver_mesh(n_shards, sub=...)"
        )
    # static halo reach: whole shards per direction (multi-hop when the
    # band is wider than a shard); None selects the dynamic unequal path
    hops = -(-hw // R) if equal else None
    if not equal and R < hw:
        raise ValueError(
            f"bandwidth {hw} > shard rows {R} needs equal shards for the "
            f"multi-hop halo path (use balanced_rows)"
        )

    raw_spmv = partial(_DIST_SPMV[spmv_name], offsets=offsets, hw=hw, axis=ax,
                       n_shards=Pn, hops=hops)
    base_reducer = make_reducer(reduce_name, ax)
    if depth > 1:
        if engine not in ("jnp", "auto"):
            raise ValueError(
                f"deep-pipeline method {method!r} runs the coordinate loop "
                f"(no {engine!r} VMA-core backend); use engine='jnp'/'auto'"
            )
        loop = make_deep_pipecg_core(depth)
        core = None
    else:
        loop = run_pipecg
        core = get_core(engine)

    # phase annotations: the distributed SPMV and the global reduction get
    # their own HLO names (per strategy), so XLA profiles attribute
    # collective time to the schedule that caused it. trace_scope adds no
    # primitives — a no-op unless repro.obs is enabled at trace time.
    def local_spmv(data, v, rows):
        with trace_scope(f"dist.spmv.{spmv_name}"):
            return raw_spmv(data, v, rows)

    def reducer_fn(*partials):
        with trace_scope(f"dist.reduce.{reduce_name}"):
            return base_reducer(*partials)

    reducer_fn.array = getattr(base_reducer, "array", None)

    spec_mat = P(ax, None, None)
    spec_vec = P(ax, None)
    spec_scalar = P(ax)
    spec_rhs = spec_vec if nrhs is None else P(ax, None, None)

    def _one_solve(data, rows, inv_diag, b, atol, rtol):
        kwargs = dict(
            spmv_fn=lambda v: local_spmv(data, v, rows),
            pc_fn=lambda r: inv_diag * r,
            reducer=reducer_fn,
            inv_diag=inv_diag,  # PC fused into the canonical core
            atol=atol,
            rtol=rtol,
            maxiter=maxiter,
            replace_every=replace_every,
        )
        if core is not None:
            kwargs["core"] = core
        return loop(b, jnp.zeros_like(b), **kwargs)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(spec_mat, spec_scalar, spec_rhs, spec_vec, P(), P()),
        out_specs=(spec_rhs, P(), P(), P(), P()),
    )
    def _solve(data_blk, rows_blk, b_blk, inv_blk, atol, rtol):
        data = data_blk[0]  # (k_diags, R)
        rows = rows_blk[0]
        b = b_blk[0]  # (R,) — or (nrhs, R) for the batched program
        inv_diag = inv_blk[0]

        if nrhs is None:
            i, x, norm, converged, hist = _one_solve(data, rows, inv_diag, b, atol, rtol)
            return x[None], i, norm, converged, hist
        # mesh-level rhs batching: ONE program, the loop vmapped over the
        # rhs axis INSIDE shard_map — each psum/ppermute carries the whole
        # batch (k-fold useful work per global reduction)
        i, x, norm, converged, hist = jax.vmap(
            lambda bb: _one_solve(data, rows, inv_diag, bb, atol, rtol)
        )(b)
        return x[None], i, norm, converged, hist

    def runner(b_sh, inv_diag_sh, atol=1e-5, rtol=0.0) -> SolveResult:
        x, iters, norm, conv, hist = _solve(
            As.data, As.rows_valid, b_sh, inv_diag_sh,
            jnp.float32(atol), jnp.float32(rtol),
        )
        shape = (Pn, R) if nrhs is None else (Pn, nrhs, R)
        return SolveResult(
            x=x.reshape(shape), iterations=iters, residual_norm=norm,
            converged=conv, history=hist,
        )

    runner.pipeline_depth = depth
    runner.reduce_name = reduce_name
    runner.spmv_name = spmv_name
    return runner


def pipecg_distributed(
    As: ShardedDIA,
    b_sh: jax.Array,
    inv_diag_sh: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "rows",
    method: str = "h3",
    engine: str = "jnp",
    atol: float = 1e-5,
    rtol: float = 0.0,
    maxiter: int = 10000,
    reducer: Optional[str] = None,
    spmv: Optional[str] = None,
    replace_every: int = 0,
) -> SolveResult:
    """One-shot distributed PIPECG on row-sharded banded A.

    Builds the shard_map program and runs it once — the convenience form of
    :func:`build_distributed_solver` (which amortizes the build across many
    right-hand sides; ``repro.plan`` goes through that path).

    As          — ShardedDIA from repro.sparse.shard_dia (halo methods may
                  use performance-model/unequal partitions; allgather
                  methods require equal).
    b_sh        — (P, R) sharded rhs from shard_vector.
    inv_diag_sh — (P, R) sharded Jacobi inverse diagonal (use ones for no PC).
    engine      — iteration-core engine for the local block ("jnp"/"pallas"/
                  "auto"), same registry as the single-device solver
                  (depth-1 methods only — the deep pipelines run the
                  coordinate loop).
    reducer / spmv / replace_every — strategy overrides and the residual-
                  replacement period (see build_distributed_solver).
    Returns SolveResult with x of shape (P, R) padded; use unshard_vector.
    """
    runner = build_distributed_solver(
        As, mesh=mesh, axis=axis, method=method, engine=engine, maxiter=maxiter,
        reducer=reducer, spmv=spmv, replace_every=replace_every,
    )
    return runner(b_sh, inv_diag_sh, atol, rtol)
