"""Distributed PIPECG over a TPU mesh — the paper's three hybrid methods.

The paper's CPU+GPU task/data split is re-targeted to inter-chip
parallelism (DESIGN.md §2). Rows of the banded operator are partitioned
across the ``rows`` mesh axis; each method is pure *configuration* of the
shared iteration loop (``core.iteration.run_pipecg``) — a distributed SPMV
strategy plus a reduction strategy (``core.reduce``):

    method   reduction          SPMV            (paper analogue)
    ------   ----------------   -------------   -----------------------------
    "h1"     3 separate psums   all_gather      Hybrid-PIPECG-1: max overlap
    "h2"     1 packed psum      all_gather      Hybrid-PIPECG-2: copy shrink
    "h3"     1 packed psum      halo ppermute   Hybrid-PIPECG-3: 2-D decomp

SPMV strategies:

``allgather`` — full-vector SPMV (N elements over the interconnect per
    SPMV, like the paper's full-vector PCIe copies); equal shards only.
``halo`` — local band part (paper's nnz1, needs only resident x) plus
    boundary corrections (nnz2) fed by a ring ``ppermute`` of
    bandwidth-sized slabs. The halo exchange is dataflow-independent of
    SPMV part 1 — exactly the overlap the paper engineers with CUDA
    streams. Supports performance-model (unequal) partitions.

All methods run the one canonical iteration core inside one
``shard_map``-ped ``lax.while_loop``; convergence scalars are replicated
via the psums. New methods = new (reducer, spmv) registry entries.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..obs.trace import trace_scope
from ..sparse.partition import ShardedDIA
from .iteration import get_core, run_pipecg
from .reduce import make_reducer
from .types import SolveResult

__all__ = [
    "pipecg_distributed",
    "build_distributed_solver",
    "make_solver_mesh",
    "spmv_halo",
    "spmv_allgather",
    "DistMethod",
    "get_method",
    "register_dist_spmv",
    "register_method",
    "method_names",
]


def make_solver_mesh(n_shards: int, axis: str = "rows") -> Mesh:
    """1-D mesh over the first n_shards devices."""
    devs = np.array(jax.devices()[:n_shards])
    return Mesh(devs, (axis,))


# ---------------------------------------------------------------------------
# distributed SPMV strategies (called inside shard_map)
# ---------------------------------------------------------------------------

def spmv_allgather(data, x, rows, offsets: Tuple[int, ...], hw: int, axis: str, n_shards: int = 0):
    """Full-vector SPMV: all_gather m, then band-multiply my row block.

    Requires equal shard sizes (rows == R on every shard). This is the
    h1/h2 communication pattern: N elements over the interconnect per
    SPMV, like the paper's full-vector PCIe copies. ``n_shards`` is part
    of the uniform strategy signature but unused (all_gather discovers it).
    """
    R = x.shape[0]
    xfull = jax.lax.all_gather(x, axis)  # (P, R)
    Pn = xfull.shape[0]
    flat = xfull.reshape(Pn * R)
    flat = jnp.concatenate([jnp.zeros((hw,), x.dtype), flat, jnp.zeros((hw,), x.dtype)])
    p = jax.lax.axis_index(axis)
    y = jnp.zeros((R,), x.dtype)
    for j, o in enumerate(offsets):
        seg = jax.lax.dynamic_slice(flat, (hw + p * R + o,), (R,))
        y = y + data[j] * seg
    del rows  # equal shards: validity handled by zero data/x padding
    return y


def spmv_halo(data, x, rows, offsets: Tuple[int, ...], hw: int, axis: str, n_shards: int):
    """2-D decomposed SPMV: local band (nnz1) + halo corrections (nnz2).

    Only two bandwidth-sized slabs cross the interconnect (ring ppermute);
    SPMV part 1 has no data dependency on them — the overlap surface.
    Supports unequal (performance-model) shard sizes via ``rows``.
    """
    R = x.shape[0]
    # --- issue halo exchange (independent of part 1) ---
    head = x[:hw]  # my first hw valid rows -> left neighbor's right halo
    tail = jax.lax.dynamic_slice(x, (rows - hw,), (hw,))  # my last hw valid rows
    right_halo = jax.lax.ppermute(head, axis, [(p, p - 1) for p in range(1, n_shards)])
    left_halo = jax.lax.ppermute(tail, axis, [(p, p + 1) for p in range(n_shards - 1)])

    # --- SPMV part 1: local columns only (paper's nnz1) ---
    y = jnp.zeros((R,), x.dtype)
    for j, o in enumerate(offsets):
        if o == 0:
            y = y + data[j] * x
        elif o > 0:
            seg = jnp.concatenate([x[o:], jnp.zeros((o,), x.dtype)])
            y = y + data[j] * seg
        else:
            seg = jnp.concatenate([jnp.zeros((-o,), x.dtype), x[:o]])
            y = y + data[j] * seg

    # --- SPMV part 2: boundary corrections (paper's nnz2) ---
    for j, o in enumerate(offsets):
        if o > 0:
            # rows [rows-o, rows) read the right neighbor's first o entries
            dslab = jax.lax.dynamic_slice(data[j], (rows - o,), (o,))
            yslab = jax.lax.dynamic_slice(y, (rows - o,), (o,))
            y = jax.lax.dynamic_update_slice(y, yslab + dslab * right_halo[:o], (rows - o,))
        elif o < 0:
            # rows [0, -o) read the left neighbor's last -o entries
            y = y.at[: -o].add(data[j][: -o] * left_halo[hw + o :])
    return y


# Uniform strategy signature:
#   fn(data, x, rows, *, offsets, hw, axis, n_shards) -> y_local
_DIST_SPMV = {"allgather": spmv_allgather, "halo": spmv_halo}


def register_dist_spmv(name: str, fn, *, overwrite: bool = False) -> None:
    """Register a distributed SPMV strategy (uniform signature above).

    Raises ValueError if ``name`` is already registered, unless
    ``overwrite=True`` — silent replacement hides plug-in clashes.
    """
    if name in _DIST_SPMV and not overwrite:
        raise ValueError(
            f"distributed SPMV strategy {name!r} already registered; pass "
            f"overwrite=True to replace it"
        )
    _DIST_SPMV[name] = fn


# ---------------------------------------------------------------------------
# methods = (reduction strategy, SPMV strategy) configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DistMethod:
    """A distributed execution strategy for the shared PIPECG core."""

    reduce: str  # core.reduce strategy name
    spmv: str  # key into _DIST_SPMV
    equal_shards_only: bool  # allgather indexes by p*R: all shards same size


_METHODS = {
    "h1": DistMethod(reduce="separate", spmv="allgather", equal_shards_only=True),
    "h2": DistMethod(reduce="packed", spmv="allgather", equal_shards_only=True),
    "h3": DistMethod(reduce="packed", spmv="halo", equal_shards_only=False),
}


def register_method(name: str, method: DistMethod, *, overwrite: bool = False) -> None:
    """Register a new (reducer, spmv) combination as a named method.

    Raises ValueError if ``name`` is already registered, unless
    ``overwrite=True`` — silent replacement hides plug-in clashes.
    """
    from .reduce import reducer_names

    if name in _METHODS and not overwrite:
        raise ValueError(
            f"distributed method {name!r} already registered; pass "
            f"overwrite=True to replace it"
        )
    if method.spmv not in _DIST_SPMV:
        raise ValueError(
            f"unknown SPMV strategy {method.spmv!r}; register it first via "
            f"register_dist_spmv (have {tuple(sorted(_DIST_SPMV))})"
        )
    if method.reduce not in reducer_names():
        raise ValueError(
            f"unknown reduction strategy {method.reduce!r}; register it first "
            f"via core.reduce.register_reducer (have {reducer_names()})"
        )
    _METHODS[name] = method


def method_names() -> Tuple[str, ...]:
    return tuple(sorted(_METHODS))


def get_method(name: str) -> DistMethod:
    """Look up a registered distributed method (for introspection/plans)."""
    if name not in _METHODS:
        raise ValueError(f"method must be one of {method_names()}, got {name}")
    return _METHODS[name]


# ---------------------------------------------------------------------------
# the distributed solver: shard_map around the shared loop
# ---------------------------------------------------------------------------

def build_distributed_solver(
    As: ShardedDIA,
    *,
    mesh: Mesh,
    axis: str = "rows",
    method: str = "h3",
    engine: str = "jnp",
    maxiter: int = 10000,
):
    """Build (once) the shard_map'd PIPECG program for one sharded operator.

    This is the setup half of the plan/execute split: validation, strategy
    lookup and the ``shard_map`` closure happen here; the returned
    ``runner(b_sh, inv_diag_sh, atol, rtol) -> SolveResult`` only executes.
    ``atol``/``rtol`` are traced arguments, so one built runner serves any
    tolerance without recompilation; callers (``repro.plan``) wrap the
    runner in a single pinned ``jax.jit``.
    """
    cfg = get_method(method)
    Pn = As.n_shards
    R = As.rows_max
    hw = As.bandwidth
    offsets = As.offsets
    sizes = np.diff(np.asarray(As.boundaries))
    if cfg.equal_shards_only and (sizes != R).any():
        raise ValueError(f"{method} requires equal shards (use balanced_rows); sizes={sizes}")

    if cfg.spmv not in _DIST_SPMV:
        raise ValueError(f"method {method!r} names unknown SPMV strategy {cfg.spmv!r}")
    raw_spmv = partial(_DIST_SPMV[cfg.spmv], offsets=offsets, hw=hw, axis=axis, n_shards=Pn)
    base_reducer = make_reducer(cfg.reduce, axis)
    core = get_core(engine)

    # phase annotations: the distributed SPMV and the global reduction get
    # their own HLO names (per strategy), so XLA profiles attribute
    # collective time to the schedule that caused it. trace_scope adds no
    # primitives — a no-op unless repro.obs is enabled at trace time.
    def local_spmv(data, v, rows):
        with trace_scope(f"dist.spmv.{cfg.spmv}"):
            return raw_spmv(data, v, rows)

    def reducer(*partials):
        with trace_scope(f"dist.reduce.{cfg.reduce}"):
            return base_reducer(*partials)

    spec_mat = P(axis, None, None)
    spec_vec = P(axis, None)
    spec_scalar = P(axis)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(spec_mat, spec_scalar, spec_vec, spec_vec, P(), P()),
        out_specs=(P(axis, None), P(), P(), P(), P()),
    )
    def _solve(data_blk, rows_blk, b_blk, inv_blk, atol, rtol):
        data = data_blk[0]  # (k, R)
        rows = rows_blk[0]
        b = b_blk[0]  # (R,)
        inv_diag = inv_blk[0]

        i, x, norm, converged, hist = run_pipecg(
            b,
            jnp.zeros_like(b),
            spmv_fn=lambda v: local_spmv(data, v, rows),
            pc_fn=lambda r: inv_diag * r,
            core=core,
            reducer=reducer,
            inv_diag=inv_diag,  # PC fused into the canonical core
            atol=atol,
            rtol=rtol,
            maxiter=maxiter,
        )
        return x[None], i, norm, converged, hist

    def runner(b_sh, inv_diag_sh, atol=1e-5, rtol=0.0) -> SolveResult:
        x, iters, norm, conv, hist = _solve(
            As.data, As.rows_valid, b_sh, inv_diag_sh,
            jnp.float32(atol), jnp.float32(rtol),
        )
        return SolveResult(
            x=x.reshape(Pn, R), iterations=iters, residual_norm=norm,
            converged=conv, history=hist,
        )

    return runner


def pipecg_distributed(
    As: ShardedDIA,
    b_sh: jax.Array,
    inv_diag_sh: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "rows",
    method: str = "h3",
    engine: str = "jnp",
    atol: float = 1e-5,
    rtol: float = 0.0,
    maxiter: int = 10000,
) -> SolveResult:
    """One-shot distributed PIPECG on row-sharded banded A.

    Builds the shard_map program and runs it once — the convenience form of
    :func:`build_distributed_solver` (which amortizes the build across many
    right-hand sides; ``repro.plan`` goes through that path).

    As          — ShardedDIA from repro.sparse.shard_dia (h3 may use
                  performance-model/unequal partitions; h1/h2 require equal).
    b_sh        — (P, R) sharded rhs from shard_vector.
    inv_diag_sh — (P, R) sharded Jacobi inverse diagonal (use ones for no PC).
    engine      — iteration-core engine for the local block ("jnp"/"pallas"/
                  "auto"), same registry as the single-device solver.
    Returns SolveResult with x of shape (P*R,) padded; use unshard_vector.
    """
    runner = build_distributed_solver(
        As, mesh=mesh, axis=axis, method=method, engine=engine, maxiter=maxiter
    )
    return runner(b_sh, inv_diag_sh, atol, rtol)
