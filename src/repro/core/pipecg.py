r"""Pipelined PCG — Algorithm 2 of the paper (Ghysels & Vanroose).

Thin single-device front-end over the shared solver loop in
``core.iteration``: the iteration core (jnp or fused-Pallas), the SPMV
engine and the (here: identity) reduction strategy are injected, so this
file holds *no* iteration math of its own. The distributed solver
(``core.distributed``) wraps the exact same loop in ``shard_map``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..sparse.spmv import spmv
from .iteration import get_core, run_pipecg
from .preconditioners import JacobiPC, apply_pc, identity
from .types import SolveResult

__all__ = ["pipecg"]


@partial(jax.jit, static_argnames=("maxiter", "engine", "spmv_engine", "replace_every"))
def _pipecg_impl(
    A, b, M, x0, atol, rtol, maxiter: int, engine: str, spmv_engine: str, replace_every: int
):
    # Jacobi fuses into the iteration core; any other PC is applied per
    # iteration by the loop (inv_diag=None -> m = pc_fn(w)).
    inv_diag = M.inv_diag if isinstance(M, JacobiPC) else None
    i, x, norm, converged, hist = run_pipecg(
        b,
        x0,
        spmv_fn=lambda v: spmv(A, v, engine=spmv_engine),
        pc_fn=lambda r: apply_pc(M, r),
        core=get_core(engine),
        inv_diag=inv_diag,
        atol=atol,
        rtol=rtol,
        maxiter=maxiter,
        replace_every=replace_every,
    )
    return SolveResult(x=x, iterations=i, residual_norm=norm, converged=converged, history=hist)


def pipecg(
    A,
    b,
    M=None,
    x0=None,
    atol: float = 1e-5,
    rtol: float = 0.0,
    maxiter: int = 10000,
    engine: str = "jnp",
    spmv_engine: str | None = None,
    replace_every: int = 0,
) -> SolveResult:
    """Solve SPD ``A x = b`` with Pipelined PCG (Algorithm 2).

    engine="jnp"    — pure-jnp iteration core (oracle).
    engine="pallas" — fused single-pass Pallas kernel for the 8 VMAs +
                      Jacobi PC + dot partials (the paper's kernel-fusion
                      optimization, §V-B, extended to fold the dots).
    engine="auto"   — pallas on TPU, jnp elsewhere.
    spmv_engine     — SPMV dispatch engine ("jnp"/"pallas"/"auto"); defaults
                      to following ``engine`` so `engine="pallas"` runs the
                      whole iteration (core AND SPMV) on Pallas kernels.
    replace_every   — if > 0, re-derive all auxiliary vectors from their
                      definitions every k iterations (residual replacement;
                      beyond-paper stability feature for low precision /
                      long runs; 0 = paper-faithful recurrences only).
    """
    if M is None:
        M = identity()
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if spmv_engine is None:
        spmv_engine = engine if engine in ("pallas", "auto") else "jnp"
    return _pipecg_impl(
        A, b, M, x0, jnp.float32(atol), jnp.float32(rtol),
        maxiter, engine, spmv_engine, replace_every,
    )
