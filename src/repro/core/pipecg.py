r"""Pipelined PCG — Algorithm 2 of the paper (Ghysels & Vanroose).

Thin single-device front-end over the shared solver loop in
``core.iteration``: the iteration core (jnp, fused-Pallas VMA, or the
whole-iteration ``fused_iter`` kernel), the SPMV engine and the (here:
identity) reduction strategy are injected, so this file holds *no*
iteration math of its own. The distributed solver (``core.distributed``)
wraps the exact same loop in ``shard_map``; its communication-reduced
siblings (``pl2``/``pl3`` depth-l pipelines, hierarchical "h4"
reduction) and the method x reducer selection matrix are documented in
docs/distributed.md.

What this file *does* own is the **padded execution path**: the Pallas
cores want LANE-aligned tiles, and padding ten vectors every iteration
would dominate the fused kernel's saving. For DIA operators with an
elementwise (Jacobi/identity) preconditioner, the solve runs entirely on
views zero-padded ONCE — operator diagonals, b, x0, inv_diag — sized so
every kernel tile constraint is met simultaneously; the while-loop body
then contains zero pad/reshape work and the solution is sliced back to n
at the end. (The DIA zero-outside-band convention makes the padded tail
invariant — it stays exactly 0 through every recurrence.) ``SolverPlan``
builds the ``fused_iter`` core once at plan time, pinning the padded
diagonal data on the plan.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..kernels.common import LANE, ceil_to, pad1d
from ..obs.trace import trace_scope
from ..sparse.formats import DIAMatrix
from ..sparse.spmv import resolve_engine, spmv, spmv_dia, spmv_dia_bf16
from .iteration import get_core, make_fused_iter_core, resolve_core_name, run_pipecg
from .preconditioners import IdentityPC, JacobiPC, apply_pc, identity
from .types import SolveResult

__all__ = ["pipecg", "pin_pipecg_core"]

# default residual-replacement period when the reduced-precision SPMV
# engine is selected and the caller did not choose one (the f32/f64
# safety net arXiv 2501.03743-style reduced-precision CG relies on)
_BF16_REPLACE_EVERY = 50


def _elementwise_pc(M) -> bool:
    return isinstance(M, (JacobiPC, IdentityPC))


def _padded_tile(core_name: str, bandwidth: int, tile: int | None) -> int:
    """One tile size satisfying every kernel constraint of this core."""
    if core_name == "fused_iter":
        from ..kernels.fused_iter import fused_iter_tile

        return fused_iter_tile(bandwidth, tile)
    # pallas VMA core + banded SPMV: align to both the fused_vma 2-D tile
    # (TILE_ROWS * LANE) and the SPMV halo (>= bandwidth, LANE-aligned)
    from ..kernels.fused_vma.kernel import TILE_ROWS

    t = max(tile or TILE_ROWS * LANE, ceil_to(bandwidth + 1, LANE))
    return ceil_to(t, TILE_ROWS * LANE)


def _padded_spmv_fns(Ap: DIAMatrix, spmv_engine: str, t: int):
    """(iteration spmv, replacement spmv) on pre-padded vectors.

    Both keep the padded tail at exactly zero. The replacement SPMV is
    always full precision — when the iteration runs the "bf16" engine it
    is the f32 (f64 under x64) safety net residual replacement re-derives
    vectors through.
    """
    eng = resolve_engine(Ap, spmv_engine)

    def _pallas(v):
        from ..kernels.spmv_dia import spmv_dia_pallas

        return spmv_dia_pallas(Ap, v, tile=t)

    full = _pallas if jax.default_backend() == "tpu" else (lambda v: spmv_dia(Ap, v))
    if eng == "pallas":
        return _pallas, _pallas
    if eng == "bf16":
        return (lambda v: spmv_dia_bf16(Ap, v)), full
    return (lambda v: spmv_dia(Ap, v)), (lambda v: spmv_dia(Ap, v))


@partial(
    jax.jit,
    static_argnames=("maxiter", "core_name", "spmv_engine", "replace_every", "tile", "core_obj"),
)
def _pipecg_impl(
    A, b, M, x0, atol, rtol,
    maxiter: int, core_name: str, spmv_engine: str, replace_every: int,
    tile, core_obj,
):
    # Jacobi fuses into the iteration core; any other PC is applied per
    # iteration by the loop (inv_diag=None -> m = pc_fn(w)).
    inv_diag = M.inv_diag if isinstance(M, JacobiPC) else None
    padded = (
        core_name in ("pallas", "fused_iter")
        and isinstance(A, DIAMatrix)
        and _elementwise_pc(M)
    )

    if not padded:
        i, x, norm, converged, hist = run_pipecg(
            b,
            x0,
            spmv_fn=lambda v: spmv(A, v, engine=spmv_engine),
            pc_fn=lambda r: apply_pc(M, r),
            core=get_core(core_name, A),
            inv_diag=inv_diag,
            atol=atol,
            rtol=rtol,
            maxiter=maxiter,
            replace_every=replace_every,
            replace_spmv_fn=(
                (lambda v: spmv(A, v, engine="auto")) if spmv_engine == "bf16" else None
            ),
        )
        return SolveResult(x=x, iterations=i, residual_norm=norm, converged=converged, history=hist)

    # ---- padded execution: pad once, run the loop pad/reshape-free ----
    n = A.n
    if core_name == "fused_iter":
        core = core_obj if core_obj is not None else make_fused_iter_core(A, tile=tile)
        t, n_pad = core.tile, core.n_pad
    else:
        core = get_core(core_name)
        t = _padded_tile(core_name, A.bandwidth, tile)
        n_pad = ceil_to(n, t)
    with trace_scope("pipecg.pad"):  # once per solve, never in the loop
        Ap = DIAMatrix(jnp.pad(A.data, ((0, 0), (0, n_pad - n))), A.offsets, n_pad)
        bp = pad1d(b, n_pad)
        x0p = pad1d(x0, n_pad)
        inv_p = pad1d(inv_diag, n_pad) if inv_diag is not None else None
        if core_name == "fused_iter" and inv_p is None:
            inv_p = jnp.ones((n_pad,), b.dtype)  # identity PC, fused elementwise
    spmv_fn, replace_fn = _padded_spmv_fns(Ap, spmv_engine, t)

    i, x, norm, converged, hist = run_pipecg(
        bp,
        x0p,
        spmv_fn=spmv_fn,
        pc_fn=(lambda r: inv_p * r) if inv_p is not None else (lambda r: r),
        core=core,
        inv_diag=inv_p,
        atol=atol,
        rtol=rtol,
        maxiter=maxiter,
        replace_every=replace_every,
        replace_spmv_fn=replace_fn,
    )
    return SolveResult(
        x=x[:n], iterations=i, residual_norm=norm, converged=converged, history=hist
    )


def _resolve_config(A, M, engine: str, spmv_engine, replace_every, core):
    """Shared engine/core/spmv/replace resolution for pipecg and plans."""
    core_name = "fused_iter" if core is not None else resolve_core_name(engine, A)
    if core_name == "fused_iter":
        if not isinstance(A, DIAMatrix):
            if engine == "auto":
                core_name = "pallas" if jax.default_backend() == "tpu" else "jnp"
            else:
                raise TypeError(
                    f"engine 'fused_iter' needs a DIAMatrix operator, got {type(A).__name__}"
                )
        elif M is not None and not _elementwise_pc(M):
            if engine == "auto":
                core_name = "pallas" if jax.default_backend() == "tpu" else "jnp"
            else:
                raise ValueError(
                    "engine 'fused_iter' fuses an elementwise preconditioner; "
                    f"use M='jacobi'/'identity', got {type(M).__name__}"
                )
    if spmv_engine is None:
        # fused_iter uses SPMV only at init/replacement -> backend default;
        # engine="pallas"/"auto" runs the whole iteration on kernels
        spmv_engine = "auto" if core_name == "fused_iter" or engine in ("pallas", "auto") else "jnp"
    if replace_every is None:
        replace_every = _BF16_REPLACE_EVERY if spmv_engine == "bf16" else 0
    return core_name, spmv_engine, int(replace_every)


def pin_pipecg_core(A, M, engine: str, spmv_engine=None, replace_every=None, tile=None):
    """Plan-time setup: build (once) the operator-pinned fused core.

    Returns the ``core`` object to thread into :func:`pipecg`, or None
    when the resolved configuration does not use one. Building here —
    rather than inside the solve trace — pins the padded diagonal views
    on the plan, so repeated solves reuse them and the while-loop body
    does zero padding/reshaping.
    """
    core_name, _, _ = _resolve_config(A, M, engine, spmv_engine, replace_every, None)
    if core_name != "fused_iter":
        return None
    return make_fused_iter_core(A, tile=tile)


def pipecg(
    A,
    b,
    M=None,
    x0=None,
    atol: float = 1e-5,
    rtol: float = 0.0,
    maxiter: int = 10000,
    engine: str = "jnp",
    spmv_engine: str | None = None,
    replace_every: int | None = None,
    tile: int | None = None,
    core=None,
) -> SolveResult:
    """Solve SPD ``A x = b`` with Pipelined PCG (Algorithm 2).

    engine="jnp"        — pure-jnp iteration core (oracle).
    engine="pallas"     — fused single-pass Pallas kernel for the 8 VMAs +
                          Jacobi PC + dot partials (paper §V-B, extended to
                          fold the dots); SPMV is a second kernel.
    engine="fused_iter" — the whole iteration (banded SPMV + VMAs + PC +
                          dot partials) as ONE Pallas kernel; requires a
                          DIAMatrix and Jacobi/identity PC.
    engine="auto"       — fused_iter on TPU when its requirements hold,
                          else pallas on TPU, jnp elsewhere.
    spmv_engine         — SPMV dispatch engine ("jnp"/"pallas"/"bf16"/
                          "auto"); defaults to "auto" for fused_iter (init
                          + residual replacement only) and to following
                          ``engine`` otherwise. "bf16" streams the band
                          data in bf16 with f32 accumulation — reduced
                          precision, half the SPMV traffic.
    replace_every       — if > 0, re-derive all auxiliary vectors from
                          their definitions (at full precision) every k
                          iterations. Default: 0, except {bf16} when
                          spmv_engine="bf16" — the residual-replacement
                          safety net reduced-precision runs require.
    tile                — row-tile override for the padded Pallas paths.
    core                — a prebuilt operator-pinned core from
                          :func:`pin_pipecg_core` (plans pass this so
                          padded views are pinned once, not per trace).
    """
    if M is None:
        M = identity()
    if x0 is None:
        x0 = jnp.zeros_like(b)
    core_name, spmv_engine, replace_every = _resolve_config(
        A, M, engine, spmv_engine, replace_every, core
    )
    return _pipecg_impl(
        A, b, M, x0, jnp.float32(atol), jnp.float32(rtol),
        maxiter, core_name, spmv_engine, replace_every, tile, core,
    )


if pipecg.__doc__:
    pipecg.__doc__ = pipecg.__doc__.replace("{bf16}", str(_BF16_REPLACE_EVERY))
