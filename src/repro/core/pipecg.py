r"""Pipelined PCG — Algorithm 2 of the paper (Ghysels & Vanroose).

Structure of one iteration (line numbers from the paper):

    scalars   beta_i, alpha_i           <- gamma/delta/alpha of it. i-1/i
    VMAs      z,q,s,p (10-13)           <- beta
    VMAs      x,r,u,w (14-17)           <- alpha
    dots      gamma', delta', ||u||     (18-20)   \   independent of
    PC        m = M^-1 w                (21)       >  each other ->
    SPMV      n = A m                   (22)      /   overlappable

The dots' results are consumed only at the *next* iteration's scalar
computation, which is the slack the paper's hybrid methods exploit. In this
single-device form the eight VMAs + PC (+ the three dot partials, one step
beyond the paper) can be fused into a single memory pass — set
``engine="pallas"`` to use the fused TPU kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..sparse.spmv import spmv
from .pcg import dot_f32
from .preconditioners import JacobiPC, apply_pc, identity
from .types import SolveResult

__all__ = ["pipecg"]


def _vma_dots_jnp(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta):
    """Reference (unfused) iteration core: 8 VMAs + PC + 3 dot partials."""
    z = n + beta * z
    q = m + beta * q
    s = w + beta * s
    p = u + beta * p
    x = x + alpha * p
    r = r - alpha * s
    u = u - alpha * q
    w = w - alpha * z
    m = inv_diag * w if inv_diag is not None else w
    gamma = dot_f32(r, u)
    delta = dot_f32(w, u)
    uu = dot_f32(u, u)
    return z, q, s, p, x, r, u, w, m, jnp.stack([gamma, delta, uu])


def _vma_dots_pallas(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta):
    from ..kernels.fused_vma import fused_vma_dots

    inv = inv_diag if inv_diag is not None else jnp.ones_like(w)
    return fused_vma_dots(z, q, s, p, x, r, u, w, n, m, inv, alpha, beta)


@partial(jax.jit, static_argnames=("maxiter", "engine", "replace_every"))
def _pipecg_impl(A, b, M, x0, atol, rtol, maxiter: int, engine: str, replace_every: int):
    dtype = b.dtype
    inv_diag = M.inv_diag if isinstance(M, JacobiPC) else None
    core = _vma_dots_pallas if engine == "pallas" else _vma_dots_jnp
    if engine == "pallas" and inv_diag is None and not isinstance(M, JacobiPC):
        # fused kernel folds the Jacobi PC; identity PC = ones
        inv_diag = jnp.ones_like(b)

    # init (lines 1-3)
    r0 = b - spmv(A, x0)
    u0 = apply_pc(M, r0)
    w0 = spmv(A, u0)
    gamma0 = dot_f32(r0, u0)
    delta0 = dot_f32(w0, u0)
    norm0 = jnp.sqrt(dot_f32(u0, u0))
    m0 = apply_pc(M, w0)
    n0 = spmv(A, m0)
    thresh = jnp.maximum(atol, rtol * norm0)
    hist0 = jnp.full((maxiter + 1,), jnp.nan, dtype=jnp.float32).at[0].set(norm0.astype(jnp.float32))
    zv = jnp.zeros_like(b)

    def cond(state):
        i = state[0]
        norm = state[-2]
        return (i < maxiter) & (norm > thresh)

    def body(state):
        (i, x, r, u, w, z, q, s, p, m, n,
         gamma, gamma_prev, delta, alpha_prev, norm, hist) = state
        # scalars (lines 5-9) — consume *previous* iteration's reductions
        beta = jnp.where(i > 0, gamma / gamma_prev, 0.0)
        alpha = jnp.where(
            i > 0, gamma / (delta - beta * gamma / alpha_prev), gamma / delta
        )
        beta_t = beta.astype(dtype)
        alpha_t = alpha.astype(dtype)
        # fused VMA pipeline + PC + dot partials (lines 10-21)
        z, q, s, p, x, r, u, w, m, dots = core(
            z, q, s, p, x, r, u, w, n, m, inv_diag, alpha_t, beta_t
        )
        if inv_diag is None:
            m = apply_pc(M, w)
        gamma_new, delta_new, uu = dots[0], dots[1], dots[2]
        # SPMV (line 22) — independent of the dots: overlap target
        n = spmv(A, m)
        norm_new = jnp.sqrt(uu)

        if replace_every > 0:
            # Residual replacement (Cools & Vanroose): periodically re-derive
            # every auxiliary vector from its definition to arrest the
            # recurrence roundoff drift that plain PIPECG accumulates.
            def _replace(args):
                x, p, *_ = args
                r = b - spmv(A, x)
                u = apply_pc(M, r)
                w = spmv(A, u)
                s = spmv(A, p)
                q = apply_pc(M, s)
                z = spmv(A, q)
                m = apply_pc(M, w)
                n = spmv(A, m)
                gamma = dot_f32(r, u)
                delta = dot_f32(w, u)
                norm = jnp.sqrt(dot_f32(u, u))
                return x, p, r, u, w, s, q, z, m, n, gamma, delta, norm

            do_rr = (i > 0) & (jnp.mod(i + 1, replace_every) == 0)
            (x, p, r, u, w, s, q, z, m, n, gamma_new, delta_new, norm_new) = jax.lax.cond(
                do_rr,
                _replace,
                lambda args: args,
                (x, p, r, u, w, s, q, z, m, n, gamma_new, delta_new, norm_new),
            )

        hist = hist.at[i + 1].set(norm_new.astype(jnp.float32))
        return (
            i + 1, x, r, u, w, z, q, s, p, m, n,
            gamma_new, gamma, delta_new, alpha, norm_new, hist,
        )

    acc = gamma0.dtype
    state = (
        jnp.int32(0), x0, r0, u0, w0, zv, zv, zv, zv, m0, n0,
        gamma0, jnp.ones((), acc), delta0, jnp.ones((), acc), norm0, hist0,
    )
    out = jax.lax.while_loop(cond, body, state)
    i, x, norm, hist = out[0], out[1], out[-2], out[-1]
    return SolveResult(x=x, iterations=i, residual_norm=norm, converged=norm <= thresh, history=hist)


def pipecg(
    A,
    b,
    M=None,
    x0=None,
    atol: float = 1e-5,
    rtol: float = 0.0,
    maxiter: int = 10000,
    engine: str = "jnp",
    replace_every: int = 0,
) -> SolveResult:
    """Solve SPD ``A x = b`` with Pipelined PCG (Algorithm 2).

    engine="jnp"    — pure-jnp iteration core (oracle).
    engine="pallas" — fused single-pass Pallas kernel for the 8 VMAs +
                      Jacobi PC + dot partials (the paper's kernel-fusion
                      optimization, §V-B, extended to fold the dots).
    replace_every   — if > 0, re-derive all auxiliary vectors from their
                      definitions every k iterations (residual replacement;
                      beyond-paper stability feature for low precision /
                      long runs; 0 = paper-faithful recurrences only).
    """
    if M is None:
        M = identity()
    if x0 is None:
        x0 = jnp.zeros_like(b)
    return _pipecg_impl(
        A, b, M, x0, jnp.float32(atol), jnp.float32(rtol), maxiter, engine, replace_every
    )
