"""Preconditioned Conjugate Gradient — Algorithm 1 of the paper.

This is the baseline every speedup in the paper is measured against
(Paralution/PETSc PCG are this algorithm). Three reductions per iteration,
each a hard synchronization point: nothing overlaps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..sparse.spmv import spmv
from .iteration import dot_f32
from .preconditioners import apply_pc, identity
from .types import SolveResult

__all__ = ["pcg", "dot_f32"]


@partial(jax.jit, static_argnames=("maxiter",))
def _pcg_impl(A, b, M, x0, atol, rtol, maxiter: int):
    dtype = b.dtype
    r0 = b - spmv(A, x0)
    u0 = apply_pc(M, r0)
    gamma0 = dot_f32(u0, r0)
    norm0 = jnp.sqrt(dot_f32(u0, u0))
    thresh = jnp.maximum(atol, rtol * norm0)

    hist0 = jnp.full((maxiter + 1,), jnp.nan, dtype=jnp.float32).at[0].set(norm0.astype(jnp.float32))
    p0 = jnp.zeros_like(b)

    def cond(state):
        i, _, _, _, _, _, _, norm, _ = state
        return (i < maxiter) & (norm > thresh)

    def body(state):
        i, x, r, u, p, gamma, gamma_prev, norm, hist = state
        beta = jnp.where(i > 0, gamma / gamma_prev, 0.0).astype(dtype)
        p = u + beta * p
        s = spmv(A, p)
        delta = dot_f32(s, p)  # reduction 1 (blocks)
        alpha = (gamma / delta).astype(dtype)
        x = x + alpha * p
        r = r - alpha * s
        u = apply_pc(M, r)
        gamma_new = dot_f32(u, r)  # reduction 2 (blocks)
        norm_new = jnp.sqrt(dot_f32(u, u))  # reduction 3 (blocks)
        hist = hist.at[i + 1].set(norm_new.astype(jnp.float32))
        return (i + 1, x, r, u, p, gamma_new, gamma, norm_new, hist)

    state = (jnp.int32(0), x0, r0, u0, p0, gamma0, jnp.ones((), gamma0.dtype), norm0, hist0)
    i, x, _, _, _, _, _, norm, hist = jax.lax.while_loop(cond, body, state)
    return SolveResult(
        x=x,
        iterations=i,
        residual_norm=norm,
        converged=norm <= thresh,
        history=hist,
    )


def pcg(A, b, M=None, x0=None, atol: float = 1e-5, rtol: float = 0.0, maxiter: int = 10000) -> SolveResult:
    """Solve SPD ``A x = b`` with PCG (Algorithm 1).

    Convergence criterion is the paper's: sqrt((u, u)) <= max(atol, rtol*norm0)
    where u is the preconditioned residual.
    """
    if M is None:
        M = identity()
    if x0 is None:
        x0 = jnp.zeros_like(b)
    return _pcg_impl(A, b, M, x0, jnp.float32(atol), jnp.float32(rtol), maxiter)
