r"""The canonical PIPECG iteration — one core, many execution strategies.

Every PIPECG execution in this repo (single-device jnp, single-device
fused-Pallas, distributed h1/h2/h3 under ``shard_map``) runs the SAME
recurrence (Ghysels & Vanroose Alg. 2, lines 10-21):

    scalars   beta_i, alpha_i           <- gamma/delta/alpha of it. i-1/i
    VMAs      z,q,s,p (10-13)           <- beta
    VMAs      x,r,u,w (14-17)           <- alpha
    dots      gamma', delta', ||u||^2   (18-20)   \   independent of
    PC        m = M^-1 w                (21)       >  each other ->
    SPMV      n = A m                   (22)      /   overlappable

The dots' results are consumed only at the *next* iteration's scalar
computation — the slack the paper's hybrid methods exploit. What differs
between executions is pure strategy, injected as three callables:

* the **iteration core** (``get_core``): how the 8 VMAs + PC + dot
  partials are evaluated — ``"jnp"`` (XLA fuses what it can),
  ``"pallas"`` (one explicit single-pass TPU kernel, paper §V-B), or
  ``"fused_iter"`` (the SPMV folded in too — ONE kernel per iteration,
  Rupp et al. arXiv 1410.4054).
* the **SPMV strategy** (``spmv_fn``): dense / DIA / BELL on one device
  (``sparse.spmv`` engine dispatch), or all-gather / halo-ppermute row
  blocks inside ``shard_map`` (``core.distributed``).
* the **reduction strategy** (``core.reduce``): identity on one device,
  three separate psums (h1) or one packed psum (h2/h3) on a mesh.

The core x operator selection matrix (see ``sparse.spmv`` for the
orthogonal SPMV-engine axis):

    core          needs                    SPMV per iteration    kernels/iter
    -----------   ----------------------   -------------------   ------------
    "jnp"         any LinearOperator       via spmv_fn           XLA-fused
    "pallas"      any LinearOperator       via spmv_fn           2 (VMA+SPMV)
    "fused_iter"  DIAMatrix, bandwidth     inside the kernel     1
                  <= tile, Jacobi or
                  identity PC
    "auto"        resolves: fused_iter on TPU when its "needs" hold,
                  else pallas on TPU, else jnp.

``"fused_iter"`` cores are built per operator (``register_core`` accepts
factories flagged ``needs_operator``) and carry ``fuses_spmv=True`` —
``run_pipecg`` then drops the carried n vector and the per-iteration
``spmv_fn`` call, since the kernel computes n = A m itself. Such cores
run on padded operands pinned once per solve (``core.pipecg``).

``run_pipecg`` is the single solver loop all of them share; there is
exactly one implementation of the recurrence in the repository
(``pipecg_vma_core``) and both Pallas kernels' oracles delegate to it.
``make_deep_pipecg_core(l)`` builds the communication-reduced sibling
loop (ONE global reduction per *l* iterations — distributed methods
``pl2``/``pl3``); the method x reducer selection matrix lives in
docs/distributed.md.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..obs.trace import trace_scope
from .reduce import Reducer, make_reducer

__all__ = [
    "dot_f32",
    "pipecg_vma_core",
    "vma_core_pallas",
    "make_fused_iter_core",
    "make_deep_pipecg_core",
    "resolve_core_name",
    "get_core",
    "core_names",
    "register_core",
    "run_pipecg",
]


def dot_f32(a: jax.Array, b: jax.Array) -> jax.Array:
    """Dot product accumulated in at-least-float32 (float64 stays float64)."""
    acc = jnp.promote_types(a.dtype, jnp.float32)
    return jnp.sum(a.astype(acc) * b.astype(acc))


# ---------------------------------------------------------------------------
# the iteration core (Alg. 2 lines 10-21 + dot partials)
# ---------------------------------------------------------------------------

def pipecg_vma_core(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta):
    """THE PIPECG recurrence: 8 VMAs + (Jacobi) PC + 3 dot partials.

    ``inv_diag`` is the fused Jacobi inverse diagonal, or None when the
    preconditioner is applied by the caller (m is then returned as w).
    Returns updated vectors plus the (local, unreduced) dot partials
    ``(gamma, delta, ||u||^2)``.
    """
    z = n + beta * z
    q = m + beta * q
    s = w + beta * s
    p = u + beta * p
    x = x + alpha * p
    r = r - alpha * s
    u = u - alpha * q
    w = w - alpha * z
    m = inv_diag * w if inv_diag is not None else w
    return z, q, s, p, x, r, u, w, m, (dot_f32(r, u), dot_f32(w, u), dot_f32(u, u))


def vma_core_pallas(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta):
    """Same contract as :func:`pipecg_vma_core` via the fused Pallas kernel."""
    from ..kernels.fused_vma import fused_vma_dots

    inv = inv_diag if inv_diag is not None else jnp.ones_like(w)
    *vecs, dots = fused_vma_dots(z, q, s, p, x, r, u, w, n, m, inv, alpha, beta)
    return (*vecs, (dots[0], dots[1], dots[2]))


def make_fused_iter_core(A, *, tile: Optional[int] = None,
                         interpret: Optional[bool] = None,
                         data_dtype=None) -> Callable:
    """Build a whole-iteration core for one DIA operator (ONE kernel/iter).

    The returned core fuses the banded SPMV n = A m into the VMA + PC +
    dot-partials pass (``kernels.fused_iter``), so ``run_pipecg`` launches
    a single Pallas kernel per iteration. It operates on *padded* vectors
    of length ``core.n_pad`` (a multiple of ``core.tile``); the padded
    diagonal data is pinned on the core at build time — build once per
    plan, not per solve. ``data_dtype`` (e.g. ``jnp.bfloat16``) stores the
    pinned diagonals in reduced precision while the kernel still
    accumulates in f32 — the mixed-precision band storage of the "bf16"
    SPMV engine, applied to the fused path.

    Attributes: ``fuses_spmv=True`` (run_pipecg drops its per-iteration
    spmv_fn call), ``n_pad``, ``tile``, ``padded_data``, ``offsets``.
    """
    from ..kernels.common import ceil_to, interpret_default
    from ..kernels.fused_iter import fused_iter_step, fused_iter_tile
    from ..sparse.formats import DIAMatrix

    if not isinstance(A, DIAMatrix):
        raise TypeError(
            f"core 'fused_iter' needs a DIAMatrix operator (its SPMV is a "
            f"fused banded kernel), got {type(A).__name__}"
        )
    t = fused_iter_tile(A.bandwidth, tile)
    n_pad = ceil_to(A.n, t)
    dp = jnp.pad(A.data, ((0, 0), (0, n_pad - A.n)))
    if data_dtype is not None:
        dp = dp.astype(data_dtype)
    if interpret is None:
        interpret = interpret_default()
    offsets = A.offsets

    def core(z, q, s, p, x, r, u, w, m, inv_diag, alpha, beta):
        inv = inv_diag if inv_diag is not None else jnp.ones_like(w)
        *vecs, dots = fused_iter_step(
            dp, offsets, z, q, s, p, x, r, u, w, m, inv, alpha, beta,
            tile=t, interpret=interpret,
        )
        return (*vecs, (dots[0], dots[1], dots[2]))

    core.fuses_spmv = True
    core.n_pad = n_pad
    core.tile = t
    core.padded_data = dp
    core.offsets = offsets
    core.interpret = interpret
    return core


make_fused_iter_core.needs_operator = True

_CORES = {
    "jnp": pipecg_vma_core,
    "pallas": vma_core_pallas,
    "fused_iter": make_fused_iter_core,
}


def register_core(name: str, core: Callable, *, overwrite: bool = False) -> None:
    """Register an alternative iteration-core engine (plug-in point).

    ``core`` is either a plain core callable (the ``pipecg_vma_core``
    contract) or, when flagged ``core.needs_operator = True``, a factory
    ``core(A, **kwargs) -> core_fn`` built per operator (the
    ``fused_iter`` pattern). Raises ValueError if ``name`` is already
    registered, unless ``overwrite=True`` — silent replacement hides
    plug-in clashes.
    """
    if name in _CORES and not overwrite:
        raise ValueError(
            f"iteration core {name!r} already registered; pass overwrite=True to replace it"
        )
    _CORES[name] = core


def core_names() -> Tuple[str, ...]:
    return tuple(sorted(_CORES))


def resolve_core_name(engine: str, A=None) -> str:
    """The core name ``get_core`` will build for this engine/operator.

    "auto" prefers, in order: "fused_iter" on TPU when the operator is a
    DIAMatrix whose bandwidth fits the kernel tile (Jacobi/identity PC
    checked by the caller), "pallas" on TPU, else "jnp" — the transparent
    fallback chain for operators the fused kernel cannot take.
    """
    if engine != "auto":
        return engine
    if jax.default_backend() != "tpu":
        return "jnp"
    from ..kernels.fused_iter import TILE
    from ..sparse.formats import DIAMatrix

    if isinstance(A, DIAMatrix) and A.bandwidth < TILE:
        return "fused_iter"
    return "pallas"


def get_core(engine: str, A=None, **factory_kwargs) -> Callable:
    """Resolve an iteration core; operator-built cores take ``A`` (+kwargs)."""
    engine = resolve_core_name(engine, A)
    if engine not in _CORES:
        raise ValueError(f"unknown iteration engine {engine!r}; have {core_names()}")
    core = _CORES[engine]
    if getattr(core, "needs_operator", False):
        return core(A, **factory_kwargs)
    return core


# ---------------------------------------------------------------------------
# the shared solver loop
# ---------------------------------------------------------------------------

def run_pipecg(
    b: jax.Array,
    x0: jax.Array,
    *,
    spmv_fn: Callable[[jax.Array], jax.Array],
    pc_fn: Callable[[jax.Array], jax.Array],
    core: Callable = pipecg_vma_core,
    reducer: Optional[Reducer] = None,
    inv_diag: Optional[jax.Array] = None,
    atol,
    rtol,
    maxiter: int,
    replace_every: int = 0,
    replace_spmv_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
):
    """One PIPECG solve, generic over SPMV / PC / core / reduction strategy.

    Must be called under ``jit`` (or inside ``shard_map``); ``maxiter`` and
    ``replace_every`` are Python ints (static). When ``inv_diag`` is given
    the core fuses the Jacobi PC; otherwise ``pc_fn`` is applied to w each
    iteration. Cores flagged ``fuses_spmv`` (``make_fused_iter_core``)
    compute n = A m inside the kernel: the loop then carries no n vector
    and issues no per-iteration ``spmv_fn`` call — ``spmv_fn`` is still
    used for init and residual replacement. ``replace_spmv_fn`` overrides
    the SPMV used by residual replacement only: the full-precision safety
    net (f32, or f64 under x64) when the iteration SPMV runs reduced
    precision (the "bf16" engine). Returns ``(iterations, x,
    residual_norm, converged, history)`` as raw arrays so callers can
    rewrap (SolveResult / shard_map out_specs).
    """
    if reducer is None:
        reducer = make_reducer("local")
    if replace_spmv_fn is None:
        replace_spmv_fn = spmv_fn
    fused_spmv = bool(getattr(core, "fuses_spmv", False))
    dtype = b.dtype

    # init (Alg. 2 lines 1-3) — trace_scope tags HLO names only (zero
    # primitives added; a no-op context unless repro.obs is enabled)
    with trace_scope("pipecg.init"):
        r0 = b - spmv_fn(x0)
        u0 = pc_fn(r0)
        w0 = spmv_fn(u0)
        gamma0, delta0, nn0 = reducer(dot_f32(r0, u0), dot_f32(w0, u0), dot_f32(u0, u0))
        norm0 = jnp.sqrt(nn0)
        m0 = pc_fn(w0)
        # a fused core computes n = A m itself; carry a width-0 placeholder
        n0 = jnp.zeros((0,), dtype) if fused_spmv else spmv_fn(m0)
    thresh = jnp.maximum(jnp.asarray(atol, norm0.dtype), jnp.asarray(rtol, norm0.dtype) * norm0)
    hist0 = jnp.full((maxiter + 1,), jnp.nan, jnp.float32).at[0].set(norm0.astype(jnp.float32))
    zv = jnp.zeros_like(b)

    def cond(state):
        i = state[0]
        norm = state[-2]
        return (i < maxiter) & (norm > thresh)

    def body(state):
        (i, x, r, u, w, z, q, s, p, m, n,
         gamma, gamma_prev, delta, alpha_prev, norm, hist) = state
        # scalars (lines 5-9) — consume *previous* iteration's reductions
        beta = jnp.where(i > 0, gamma / gamma_prev, 0.0)
        alpha = jnp.where(
            i > 0, gamma / (delta - beta * gamma / alpha_prev), gamma / delta
        )
        # the one canonical core (lines 10-21; +22 when the core fuses it)
        with trace_scope("pipecg.iteration.core"):
            if fused_spmv:
                z, q, s, p, x, r, u, w, m, (g_p, d_p, n_p) = core(
                    z, q, s, p, x, r, u, w, m, inv_diag, alpha.astype(dtype), beta.astype(dtype)
                )
            else:
                z, q, s, p, x, r, u, w, m, (g_p, d_p, n_p) = core(
                    z, q, s, p, x, r, u, w, n, m, inv_diag, alpha.astype(dtype), beta.astype(dtype)
                )
                if inv_diag is None:
                    m = pc_fn(w)  # general (non-fused) preconditioner
        # the reduction(s): results consumed next iteration only
        with trace_scope("pipecg.iteration.reduce"):
            gamma_new, delta_new, uu = reducer(g_p, d_p, n_p)
        if not fused_spmv:
            # SPMV (line 22) — independent of the reductions: overlap target
            with trace_scope("pipecg.iteration.spmv"):
                n = spmv_fn(m)
        norm_new = jnp.sqrt(uu)

        if replace_every > 0:
            # Residual replacement (Cools & Vanroose): periodically re-derive
            # every auxiliary vector from its definition to arrest the
            # recurrence roundoff drift that plain PIPECG accumulates.
            def _replace(args):
                with trace_scope("pipecg.residual_replacement"):
                    return _replace_body(args)

            def _replace_body(args):
                x, p, *_ = args
                r = b - replace_spmv_fn(x)
                u = pc_fn(r)
                w = replace_spmv_fn(u)
                s = replace_spmv_fn(p)
                q = pc_fn(s)
                z = replace_spmv_fn(q)
                m = pc_fn(w)
                n = jnp.zeros((0,), dtype) if fused_spmv else replace_spmv_fn(m)
                gamma, delta, nn = reducer(dot_f32(r, u), dot_f32(w, u), dot_f32(u, u))
                return x, p, r, u, w, s, q, z, m, n, gamma, delta, jnp.sqrt(nn)

            do_rr = (i > 0) & (jnp.mod(i + 1, replace_every) == 0)
            (x, p, r, u, w, s, q, z, m, n, gamma_new, delta_new, norm_new) = jax.lax.cond(
                do_rr,
                _replace,
                lambda args: args,
                (x, p, r, u, w, s, q, z, m, n, gamma_new, delta_new, norm_new),
            )

        hist = hist.at[i + 1].set(norm_new.astype(jnp.float32))
        return (
            i + 1, x, r, u, w, z, q, s, p, m, n,
            gamma_new, gamma, delta_new, alpha, norm_new, hist,
        )

    acc = gamma0.dtype
    state = (
        jnp.int32(0), x0, r0, u0, w0, zv, zv, zv, zv, m0, n0,
        gamma0, jnp.ones((), acc), delta0, jnp.ones((), acc), norm0, hist0,
    )
    out = jax.lax.while_loop(cond, body, state)
    i, x, norm, hist = out[0], out[1], out[-2], out[-1]
    return i, x, norm, norm <= thresh, hist


# ---------------------------------------------------------------------------
# depth-l pipelined (communication-reduced) CG — ONE reduction per l steps
# ---------------------------------------------------------------------------

def make_deep_pipecg_core(l: int):
    r"""Build the depth-``l`` pipelined CG solver loop (1 reduction / l its).

    PIPECG hides ONE global reduction behind ONE SPMV; once the reduction
    latency exceeds an SPMV, that slack is spent and strong scaling stalls
    (ROADMAP item 2, after Cornelis/Cools/Vanroose arXiv 1801.04728 and
    Cools et al. arXiv 1905.06850). The depth-``l`` methods attack the
    same bound by *amortization*: the while-loop body advances ``l`` CG
    iterations on extra Krylov-basis recurrences and performs exactly ONE
    packed global reduction — a (2l+1)x(2l+1) Gram matrix psum — per
    body. The jaxpr census over the while body proves it: 1 ``psum`` per
    ``l`` iterations, vs 1 per iteration for pipecg.

    Per outer step on the split-preconditioned operator
    ``At = D^{-1/2} A D^{-1/2}`` (Jacobi/identity only — exactly what the
    distributed methods support; CG on ``At`` generates the same iterates
    as Jacobi-PCG on ``A`` in exact arithmetic):

    * **Z-basis recurrences** — the monomial bases
      ``P_j = At^j p`` (j=0..l) and ``R_j = At^j r`` (j=0..l-1):
      ``2l-1`` SPMVs, no communication beyond the SPMV's own halo.
    * **ONE reduction** — the stacked Gram matrices ``V^T V`` and
      ``V^T D^{-1} V`` of the basis ``V = [P | R]``, reduced through the
      reducer's ``.array`` strategy (``core.reduce``).
    * **l coordinate iterations** — classic CG steps carried as
      length-(2l+1) coordinate vectors; every dot product is a tiny
      ``c^T G c`` form, so no further communication. Per-lane convergence
      masking keeps iteration counts exact (a solve that converges at
      iteration 7 under ``pl3`` reports 7, not 9).
    * **recurrence->vector recovery** + optional full-precision residual
      replacement (``replace_every``), the same safety net ``run_pipecg``
      uses, rounded to outer-step cadence.

    The trade is explicit: reduction *count* drops ``l``-fold while SPMV
    count rises to ``(2l-1)/l`` per iteration — the right exchange when
    the global reduction latency, not local bandwidth, bounds scaling
    (see docs/distributed.md for the selection matrix).

    Returns a loop with the :func:`run_pipecg` signature (so
    ``build_distributed_solver`` swaps it in transparently), tagged
    ``pipeline_depth = l``. Requires an elementwise preconditioner
    passed as ``inv_diag`` (None = identity); ``pc_fn``/``core`` are
    accepted for signature compatibility and must be None/elementwise.
    """
    if l < 1:
        raise ValueError(f"pipeline depth must be >= 1, got {l}")
    m = 2 * l + 1  # basis size: P_0..P_l, R_0..R_{l-1}

    # static shift matrix: coordinates of (At v) from coordinates of v.
    # Columns l (P_l) and 2l (R_{l-1}) are zero — the inner CG steps never
    # apply At to a vector reaching those basis tails (degree argument:
    # p_j uses P_{<=j}, R_{<=j-1} for j < l).
    import numpy as _np

    S_np = _np.zeros((m, m), dtype=_np.float32)
    for j in range(l):
        S_np[j + 1, j] = 1.0
    for j in range(l - 1):
        S_np[l + 2 + j, l + 1 + j] = 1.0

    def run_deep_pipecg(
        b: jax.Array,
        x0: jax.Array,
        *,
        spmv_fn: Callable[[jax.Array], jax.Array],
        pc_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
        core: Optional[Callable] = None,
        reducer: Optional[Reducer] = None,
        inv_diag: Optional[jax.Array] = None,
        atol,
        rtol,
        maxiter: int,
        replace_every: int = 0,
        replace_spmv_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
    ):
        del pc_fn, core  # elementwise PC only; fused via inv_diag
        if reducer is None:
            reducer = make_reducer("local")
        reduce_array = getattr(reducer, "array", None)
        if reduce_array is None:
            raise ValueError(
                "deep-pipeline methods need a reducer with an '.array' "
                "reduction (all core.reduce strategies have one; attach "
                "reducer.array = ... on custom reducers)"
            )
        if replace_spmv_fn is None:
            replace_spmv_fn = spmv_fn
        dtype = b.dtype
        acc = jnp.promote_types(dtype, jnp.float32)
        S = jnp.asarray(S_np, acc)

        # split preconditioning: solve At xt = bt with At = D^-1/2 A D^-1/2
        if inv_diag is not None:
            isd = jnp.sqrt(inv_diag)
            dsq = jnp.where(isd > 0, 1.0 / jnp.where(isd > 0, isd, 1.0), 0.0)
        else:
            isd = dsq = None

        def _split(v):
            return isd * v if isd is not None else v

        def _At(v, raw=spmv_fn):
            return _split(raw(_split(v)))

        with trace_scope("deep_pipecg.init"):
            bt = _split(b)
            xt0 = dsq * x0 if dsq is not None else x0
            rt0 = bt - _At(xt0)
            # convergence metric matches run_pipecg: ||u|| with u = D^-1 r
            # = D^-1/2 rt, i.e. rt^T D^-1 rt — one init-only reduction
            nn_part = dot_f32(rt0, inv_diag * rt0 if inv_diag is not None else rt0)
            norm0 = jnp.sqrt(reducer(nn_part, nn_part, nn_part)[2])
        thresh = jnp.maximum(
            jnp.asarray(atol, norm0.dtype), jnp.asarray(rtol, norm0.dtype) * norm0
        )
        # +1 slack slot: sentinel writes from masked (converged/past-maxiter)
        # inner steps land at maxiter+1 and are sliced off at the end
        hist0 = jnp.full((maxiter + 2,), jnp.nan, jnp.float32).at[0].set(
            norm0.astype(jnp.float32)
        )
        rr_outer = max(1, -(-replace_every // l)) if replace_every > 0 else 0

        def cond(state):
            i = state[0]
            norm = state[-2]
            return (i < maxiter) & (norm > thresh)

        def body(state):
            i, o, xt, rt, p, norm, hist = state

            # --- Z-basis recurrences: 2l-1 SPMVs, zero extra reductions ---
            with trace_scope("deep_pipecg.basis"):
                basis = [p]
                for _ in range(l):
                    basis.append(_At(basis[-1]))
                basis.append(rt)
                for _ in range(l - 1):
                    basis.append(_At(basis[-1]))
                V = jnp.stack(basis)  # (m, R)

            # --- the ONE global reduction per l iterations ---
            with trace_scope("deep_pipecg.gram"):
                Va = V.astype(acc)
                G_loc = Va @ Va.T
                if inv_diag is not None:
                    H_loc = (Va * inv_diag.astype(acc)) @ Va.T
                    G, H = reduce_array(jnp.stack([G_loc, H_loc]))
                else:
                    G = H = reduce_array(G_loc)

            # --- l CG iterations in coordinates (no communication) ---
            with trace_scope("deep_pipecg.coordinate_steps"):
                pc = jnp.zeros((m,), acc).at[0].set(1.0)
                rc = jnp.zeros((m,), acc).at[l + 1].set(1.0)
                xc = jnp.zeros((m,), acc)
                for j in range(l):
                    active = (norm > thresh) & (i < maxiter)
                    sc = S @ pc  # coordinates of At p
                    rr = rc @ (G @ rc)
                    pAp = pc @ (G @ sc)
                    alpha = rr / pAp
                    xc_n = xc + alpha * pc
                    rc_n = rc - alpha * sc
                    beta = (rc_n @ (G @ rc_n)) / rr
                    pc_n = rc_n + beta * pc
                    norm_n = jnp.sqrt(jnp.maximum(rc_n @ (H @ rc_n), 0.0))
                    xc = jnp.where(active, xc_n, xc)
                    rc = jnp.where(active, rc_n, rc)
                    pc = jnp.where(active, pc_n, pc)
                    norm = jnp.where(active, norm_n.astype(norm.dtype), norm)
                    idx = jnp.where(active, i + 1, maxiter + 1)  # sentinel slot
                    hist = hist.at[idx].set(norm_n.astype(jnp.float32))
                    i = i + active.astype(jnp.int32)

            # --- recover the full vectors from their coordinates ---
            with trace_scope("deep_pipecg.recover"):
                xt = xt + (xc.astype(dtype) @ V)
                rt = (rc.astype(dtype) @ V)
                p = (pc.astype(dtype) @ V)

            if rr_outer > 0:
                # Residual replacement at outer-step cadence: re-derive the
                # true (split) residual at full precision to arrest the
                # coordinate-recurrence drift — the deep-pipeline analogue
                # of run_pipecg's replace_every safety net.
                def _replace(args):
                    xt_, rt_ = args
                    with trace_scope("deep_pipecg.residual_replacement"):
                        return xt_, bt - _At(xt_, raw=replace_spmv_fn)

                xt, rt = jax.lax.cond(
                    jnp.mod(o + 1, rr_outer) == 0, _replace, lambda a: a, (xt, rt)
                )

            return (i, o + 1, xt, rt, p, norm, hist)

        state = (jnp.int32(0), jnp.int32(0), xt0, rt0, rt0, norm0, hist0)
        out = jax.lax.while_loop(cond, body, state)
        i, xt, norm, hist = out[0], out[2], out[-2], out[-1]
        x = _split(xt)  # back-transform: x = D^-1/2 xt
        return i, x, norm, norm <= thresh, hist[: maxiter + 1]

    run_deep_pipecg.pipeline_depth = l
    run_deep_pipecg.spmvs_per_iteration = (2 * l - 1) / l
    return run_deep_pipecg
