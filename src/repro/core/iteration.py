r"""The canonical PIPECG iteration — one core, many execution strategies.

Every PIPECG execution in this repo (single-device jnp, single-device
fused-Pallas, distributed h1/h2/h3 under ``shard_map``) runs the SAME
recurrence (Ghysels & Vanroose Alg. 2, lines 10-21):

    scalars   beta_i, alpha_i           <- gamma/delta/alpha of it. i-1/i
    VMAs      z,q,s,p (10-13)           <- beta
    VMAs      x,r,u,w (14-17)           <- alpha
    dots      gamma', delta', ||u||^2   (18-20)   \   independent of
    PC        m = M^-1 w                (21)       >  each other ->
    SPMV      n = A m                   (22)      /   overlappable

The dots' results are consumed only at the *next* iteration's scalar
computation — the slack the paper's hybrid methods exploit. What differs
between executions is pure strategy, injected as three callables:

* the **iteration core** (``get_core``): how the 8 VMAs + PC + dot
  partials are evaluated — ``"jnp"`` (XLA fuses what it can) or
  ``"pallas"`` (one explicit single-pass TPU kernel, paper §V-B).
* the **SPMV strategy** (``spmv_fn``): dense / DIA / BELL on one device
  (``sparse.spmv`` engine dispatch), or all-gather / halo-ppermute row
  blocks inside ``shard_map`` (``core.distributed``).
* the **reduction strategy** (``core.reduce``): identity on one device,
  three separate psums (h1) or one packed psum (h2/h3) on a mesh.

``run_pipecg`` is the single solver loop all of them share; there is
exactly one implementation of the recurrence in the repository
(``pipecg_vma_core``) and the Pallas kernel's oracle delegates to it.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .reduce import Reducer, make_reducer

__all__ = [
    "dot_f32",
    "pipecg_vma_core",
    "vma_core_pallas",
    "get_core",
    "core_names",
    "register_core",
    "run_pipecg",
]


def dot_f32(a: jax.Array, b: jax.Array) -> jax.Array:
    """Dot product accumulated in at-least-float32 (float64 stays float64)."""
    acc = jnp.promote_types(a.dtype, jnp.float32)
    return jnp.sum(a.astype(acc) * b.astype(acc))


# ---------------------------------------------------------------------------
# the iteration core (Alg. 2 lines 10-21 + dot partials)
# ---------------------------------------------------------------------------

def pipecg_vma_core(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta):
    """THE PIPECG recurrence: 8 VMAs + (Jacobi) PC + 3 dot partials.

    ``inv_diag`` is the fused Jacobi inverse diagonal, or None when the
    preconditioner is applied by the caller (m is then returned as w).
    Returns updated vectors plus the (local, unreduced) dot partials
    ``(gamma, delta, ||u||^2)``.
    """
    z = n + beta * z
    q = m + beta * q
    s = w + beta * s
    p = u + beta * p
    x = x + alpha * p
    r = r - alpha * s
    u = u - alpha * q
    w = w - alpha * z
    m = inv_diag * w if inv_diag is not None else w
    return z, q, s, p, x, r, u, w, m, (dot_f32(r, u), dot_f32(w, u), dot_f32(u, u))


def vma_core_pallas(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta):
    """Same contract as :func:`pipecg_vma_core` via the fused Pallas kernel."""
    from ..kernels.fused_vma import fused_vma_dots

    inv = inv_diag if inv_diag is not None else jnp.ones_like(w)
    *vecs, dots = fused_vma_dots(z, q, s, p, x, r, u, w, n, m, inv, alpha, beta)
    return (*vecs, (dots[0], dots[1], dots[2]))


_CORES = {"jnp": pipecg_vma_core, "pallas": vma_core_pallas}


def register_core(name: str, core: Callable, *, overwrite: bool = False) -> None:
    """Register an alternative iteration-core engine (plug-in point).

    Raises ValueError if ``name`` is already registered, unless
    ``overwrite=True`` — silent replacement hides plug-in clashes.
    """
    if name in _CORES and not overwrite:
        raise ValueError(
            f"iteration core {name!r} already registered; pass overwrite=True to replace it"
        )
    _CORES[name] = core


def core_names() -> Tuple[str, ...]:
    return tuple(sorted(_CORES))


def get_core(engine: str) -> Callable:
    if engine == "auto":
        engine = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if engine not in _CORES:
        raise ValueError(f"unknown iteration engine {engine!r}; have {core_names()}")
    return _CORES[engine]


# ---------------------------------------------------------------------------
# the shared solver loop
# ---------------------------------------------------------------------------

def run_pipecg(
    b: jax.Array,
    x0: jax.Array,
    *,
    spmv_fn: Callable[[jax.Array], jax.Array],
    pc_fn: Callable[[jax.Array], jax.Array],
    core: Callable = pipecg_vma_core,
    reducer: Optional[Reducer] = None,
    inv_diag: Optional[jax.Array] = None,
    atol,
    rtol,
    maxiter: int,
    replace_every: int = 0,
):
    """One PIPECG solve, generic over SPMV / PC / core / reduction strategy.

    Must be called under ``jit`` (or inside ``shard_map``); ``maxiter`` and
    ``replace_every`` are Python ints (static). When ``inv_diag`` is given
    the core fuses the Jacobi PC; otherwise ``pc_fn`` is applied to w each
    iteration. Returns ``(iterations, x, residual_norm, converged, history)``
    as raw arrays so callers can rewrap (SolveResult / shard_map out_specs).
    """
    if reducer is None:
        reducer = make_reducer("local")
    dtype = b.dtype

    # init (Alg. 2 lines 1-3)
    r0 = b - spmv_fn(x0)
    u0 = pc_fn(r0)
    w0 = spmv_fn(u0)
    gamma0, delta0, nn0 = reducer(dot_f32(r0, u0), dot_f32(w0, u0), dot_f32(u0, u0))
    norm0 = jnp.sqrt(nn0)
    m0 = pc_fn(w0)
    n0 = spmv_fn(m0)
    thresh = jnp.maximum(jnp.asarray(atol, norm0.dtype), jnp.asarray(rtol, norm0.dtype) * norm0)
    hist0 = jnp.full((maxiter + 1,), jnp.nan, jnp.float32).at[0].set(norm0.astype(jnp.float32))
    zv = jnp.zeros_like(b)

    def cond(state):
        i = state[0]
        norm = state[-2]
        return (i < maxiter) & (norm > thresh)

    def body(state):
        (i, x, r, u, w, z, q, s, p, m, n,
         gamma, gamma_prev, delta, alpha_prev, norm, hist) = state
        # scalars (lines 5-9) — consume *previous* iteration's reductions
        beta = jnp.where(i > 0, gamma / gamma_prev, 0.0)
        alpha = jnp.where(
            i > 0, gamma / (delta - beta * gamma / alpha_prev), gamma / delta
        )
        # the one canonical core (lines 10-21)
        z, q, s, p, x, r, u, w, m, (g_p, d_p, n_p) = core(
            z, q, s, p, x, r, u, w, n, m, inv_diag, alpha.astype(dtype), beta.astype(dtype)
        )
        if inv_diag is None:
            m = pc_fn(w)  # general (non-fused) preconditioner
        # the reduction(s): results consumed next iteration only
        gamma_new, delta_new, uu = reducer(g_p, d_p, n_p)
        # SPMV (line 22) — independent of the reductions: overlap target
        n = spmv_fn(m)
        norm_new = jnp.sqrt(uu)

        if replace_every > 0:
            # Residual replacement (Cools & Vanroose): periodically re-derive
            # every auxiliary vector from its definition to arrest the
            # recurrence roundoff drift that plain PIPECG accumulates.
            def _replace(args):
                x, p, *_ = args
                r = b - spmv_fn(x)
                u = pc_fn(r)
                w = spmv_fn(u)
                s = spmv_fn(p)
                q = pc_fn(s)
                z = spmv_fn(q)
                m = pc_fn(w)
                n = spmv_fn(m)
                gamma, delta, nn = reducer(dot_f32(r, u), dot_f32(w, u), dot_f32(u, u))
                return x, p, r, u, w, s, q, z, m, n, gamma, delta, jnp.sqrt(nn)

            do_rr = (i > 0) & (jnp.mod(i + 1, replace_every) == 0)
            (x, p, r, u, w, s, q, z, m, n, gamma_new, delta_new, norm_new) = jax.lax.cond(
                do_rr,
                _replace,
                lambda args: args,
                (x, p, r, u, w, s, q, z, m, n, gamma_new, delta_new, norm_new),
            )

        hist = hist.at[i + 1].set(norm_new.astype(jnp.float32))
        return (
            i + 1, x, r, u, w, z, q, s, p, m, n,
            gamma_new, gamma, delta_new, alpha, norm_new, hist,
        )

    acc = gamma0.dtype
    state = (
        jnp.int32(0), x0, r0, u0, w0, zv, zv, zv, zv, m0, n0,
        gamma0, jnp.ones((), acc), delta0, jnp.ones((), acc), norm0, hist0,
    )
    out = jax.lax.while_loop(cond, body, state)
    i, x, norm, hist = out[0], out[1], out[-2], out[-1]
    return i, x, norm, norm <= thresh, hist
