"""Chronopoulos–Gear CG: one synchronization per iteration.

The stepping stone between PCG (3 reductions) and PIPECG (1 *overlapped*
reduction): the two recurrence dot products (and the convergence norm) are
computed back-to-back so they reduce in a single fused synchronization, but
the result is still consumed in the same iteration — no overlap slack.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..sparse.spmv import spmv
from .pcg import dot_f32
from .preconditioners import apply_pc, identity
from .types import SolveResult

__all__ = ["chronopoulos_cg"]


@partial(jax.jit, static_argnames=("maxiter",))
def _cg_cg_impl(A, b, M, x0, atol, rtol, maxiter: int):
    dtype = b.dtype
    r0 = b - spmv(A, x0)
    u0 = apply_pc(M, r0)
    w0 = spmv(A, u0)
    gamma0 = dot_f32(r0, u0)
    delta0 = dot_f32(w0, u0)
    norm0 = jnp.sqrt(dot_f32(u0, u0))
    thresh = jnp.maximum(atol, rtol * norm0)
    alpha0 = gamma0 / delta0

    hist0 = jnp.full((maxiter + 1,), jnp.nan, dtype=jnp.float32).at[0].set(norm0.astype(jnp.float32))
    z = jnp.zeros_like(b)

    def cond(state):
        i, *_, norm, _ = state
        return (i < maxiter) & (norm > thresh)

    def body(state):
        i, x, r, u, w, p, s, alpha, beta, gamma, norm, hist = state
        p = u + beta * p
        s = w + beta * s
        x = x + alpha * p
        r = r - alpha * s
        u = apply_pc(M, r)
        w = spmv(A, u)
        # single synchronization: the three dots reduce together
        gamma_new = dot_f32(r, u)
        delta = dot_f32(w, u)
        norm_new = jnp.sqrt(dot_f32(u, u))
        beta_new = (gamma_new / gamma).astype(dtype)
        alpha_new = (gamma_new / (delta - beta_new * gamma_new / alpha)).astype(dtype)
        hist = hist.at[i + 1].set(norm_new.astype(jnp.float32))
        return (i + 1, x, r, u, w, p, s, alpha_new, beta_new, gamma_new, norm_new, hist)

    state = (
        jnp.int32(0), x0, r0, u0, w0, z, z,
        alpha0.astype(dtype), jnp.zeros((), dtype), gamma0, norm0, hist0,
    )
    out = jax.lax.while_loop(cond, body, state)
    i, x, norm, hist = out[0], out[1], out[-2], out[-1]
    return SolveResult(x=x, iterations=i, residual_norm=norm, converged=norm <= thresh, history=hist)


def chronopoulos_cg(A, b, M=None, x0=None, atol: float = 1e-5, rtol: float = 0.0, maxiter: int = 10000) -> SolveResult:
    if M is None:
        M = identity()
    if x0 is None:
        x0 = jnp.zeros_like(b)
    return _cg_cg_impl(A, b, M, x0, jnp.float32(atol), jnp.float32(rtol), maxiter)
