"""Preconditioners.

The paper (§V-A) uses the Jacobi (diagonal) preconditioner: cheap setup,
satisfactory conditioning, and — crucially for the fused kernels — an
elementwise apply that fuses into the vector-update pipeline.

All preconditioners are represented as a pytree ``M`` + ``apply(M, r)``
so they pass through jit/shard_map transparently.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from ..sparse.formats import BellMatrix, DIAMatrix

__all__ = ["JacobiPC", "IdentityPC", "BlockJacobiPC", "jacobi", "identity", "block_jacobi", "apply_pc"]


@partial(jax.tree_util.register_dataclass, data_fields=["inv_diag"], meta_fields=[])
@dataclass(frozen=True)
class JacobiPC:
    inv_diag: jax.Array  # (n,)


@partial(jax.tree_util.register_dataclass, data_fields=[], meta_fields=[])
@dataclass(frozen=True)
class IdentityPC:
    pass


@partial(jax.tree_util.register_dataclass, data_fields=["inv_blocks"], meta_fields=["block"])
@dataclass(frozen=True)
class BlockJacobiPC:
    """Dense-inverted diagonal blocks (beyond-paper baseline strengthener)."""

    inv_blocks: jax.Array  # (n//block, block, block)
    block: int


def jacobi(A) -> JacobiPC:
    d = A.diagonal()
    return JacobiPC(inv_diag=jnp.where(d != 0, 1.0 / d, 1.0).astype(d.dtype))


def identity(A=None) -> IdentityPC:
    return IdentityPC()


def block_jacobi(A, block: int = 4) -> BlockJacobiPC:
    """Extract (and invert) diagonal blocks from a DIA/BELL matrix."""
    n = A.n
    if n % block:
        raise ValueError(f"n={n} not divisible by block={block}")
    nb = n // block
    blocks = jnp.zeros((nb, block, block), dtype=A.dtype)
    if isinstance(A, DIAMatrix):
        for j, o in enumerate(A.offsets):
            if abs(o) >= block:
                continue
            # entry (i, i+o) lands in block i//block iff (i % block) + o in [0, block)
            i = jnp.arange(n)
            li = i % block
            ok = (li + o >= 0) & (li + o < block) & (i + o >= 0) & (i + o < n)
            vals = jnp.where(ok, A.data[j], 0.0)
            b = i // block
            blocks = blocks.at[b, li, jnp.clip(li + o, 0, block - 1)].add(
                jnp.where(ok, vals, 0.0)
            )
    elif isinstance(A, BellMatrix):
        i = jnp.arange(n)[:, None]
        li = i % block
        lj = A.cols % block
        same = (A.cols // block) == (i // block)
        b = (i // block) * jnp.ones_like(A.cols)
        blocks = blocks.at[b.ravel(), (li * jnp.ones_like(A.cols)).ravel(), lj.ravel()].add(
            jnp.where(same, A.vals, 0.0).ravel()
        )
    else:
        raise TypeError(type(A))
    inv = jnp.linalg.inv(blocks.astype(jnp.float32)).astype(A.dtype)
    return BlockJacobiPC(inv_blocks=inv, block=block)


def apply_pc(M, r: jax.Array) -> jax.Array:
    if isinstance(M, JacobiPC):
        return M.inv_diag * r
    if isinstance(M, IdentityPC):
        return r
    if isinstance(M, BlockJacobiPC):
        nb = M.inv_blocks.shape[0]
        rb = r.reshape(nb, M.block)
        return jnp.einsum("bij,bj->bi", M.inv_blocks, rb).reshape(-1)
    raise TypeError(type(M))
