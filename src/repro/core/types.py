"""Shared solver types."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["x", "iterations", "residual_norm", "converged", "history"],
    meta_fields=[],
)
@dataclass(frozen=True)
class SolveResult:
    """Result of a CG-family solve.

    ``history`` holds the preconditioned residual norm sqrt((u,u)) per
    iteration (the paper's convergence criterion), padded with NaN past
    convergence. Shape (maxiter+1,).
    """

    x: jax.Array
    iterations: jax.Array  # int32 scalar
    residual_norm: jax.Array  # float scalar
    converged: jax.Array  # bool scalar
    history: jax.Array  # (maxiter+1,)
