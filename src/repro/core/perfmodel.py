"""Performance model — paper §IV-C1, generalized to N devices.

The paper times 5 SPMV executions on CPU and GPU, converts to throughputs
s_dev = nnz / t_dev, and splits nnz proportionally. Here the same model
drives (a) the initial row partition across chips and (b) *continuous*
re-balancing: per-device step times are tracked with an EWMA and a
re-partition is proposed when the imbalance exceeds a threshold — that is
the straggler-mitigation loop (a slow chip gets fewer rows), and it doubles
as heterogeneous-fleet support.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from ..sparse.formats import DIAMatrix
from ..sparse.partition import balanced_nnz
from ..sparse.spmv import spmv_dia

__all__ = ["measure_spmv_time", "relative_weights", "decompose", "StragglerTracker"]


def measure_spmv_time(A: DIAMatrix, runs: int = 5) -> float:
    """Median wall time of ``runs`` SPMV executions (paper: 5 runs so cache
    effects of later iterations are represented)."""
    x = jax.numpy.ones((A.n,), A.dtype)
    f = jax.jit(lambda v: spmv_dia(A, v))
    f(x).block_until_ready()  # compile outside the timed region
    times = []
    for _ in range(runs):
        t0 = time.perf_counter()
        f(x).block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def relative_weights(times_or_speeds: np.ndarray, *, are_times: bool = True) -> np.ndarray:
    """r_dev = s_dev / sum(s): the paper's relative-performance formula."""
    v = np.asarray(times_or_speeds, dtype=np.float64)
    speeds = 1.0 / v if are_times else v
    return speeds / speeds.sum()


def decompose(A: DIAMatrix, n_parts: int, weights: np.ndarray | None = None) -> np.ndarray:
    """Row boundaries so nnz per part ~ weight (paper's N_cpu derivation)."""
    data = np.asarray(A.data)
    row_nnz = (data != 0).sum(axis=0)
    return balanced_nnz(row_nnz, n_parts, weights)


@dataclass
class StragglerTracker:
    """EWMA per-device step-time tracker -> re-partition trigger.

    The paper's performance model run continuously: feed observed per-device
    times each step; when max/min EWMA exceeds ``imbalance_threshold`` the
    tracker recommends new weights (inverse EWMA times).
    """

    n_devices: int
    alpha: float = 0.2
    imbalance_threshold: float = 1.25
    ewma: np.ndarray | None = field(default=None)

    def update(self, step_times: np.ndarray) -> None:
        t = np.asarray(step_times, dtype=np.float64)
        if self.ewma is None:
            self.ewma = t.copy()
        else:
            self.ewma = self.alpha * t + (1 - self.alpha) * self.ewma

    @property
    def imbalance(self) -> float:
        if self.ewma is None:
            return 1.0
        return float(self.ewma.max() / max(self.ewma.min(), 1e-12))

    def needs_rebalance(self) -> bool:
        return self.imbalance > self.imbalance_threshold

    def proposed_weights(self) -> np.ndarray:
        if self.ewma is None:
            return np.ones(self.n_devices) / self.n_devices
        return relative_weights(self.ewma, are_times=True)
