"""Reduction strategies for the PIPECG dot products.

One iteration of PIPECG produces three scalar partials — gamma = (r, u),
delta = (w, u) and ||u||^2 = (u, u). *How* those partials become global
scalars is the axis along which the paper's hybrid methods differ, so it
is factored out as a strategy the shared iteration core is parameterized
over (``core.iteration.run_pipecg``):

``local``     — identity: the partials already are the global dots
                (single-device execution).
``separate``  — three independent ``psum`` collectives (Hybrid-PIPECG-1:
                the paper's three separate async copies, maximally
                overlappable but 3x the collective count).
``packed``    — the three partials stacked into ONE length-3 ``psum``
                (Hybrid-PIPECG-2/3: the paper's copy-shrinking trick
                applied to reduction latency, 3 collectives -> 1).
``h4``        — hierarchical two-stage reduction on a 2-D (pod, sub)
                mesh: ONE packed psum over the fast intra-pod sub-axis,
                then ONE packed psum over the slow inter-pod axis. The
                inter-pod stage is the only collective that crosses the
                slow network boundary, and in PIPECG its result is not
                consumed until the *next* iteration's scalar step — the
                one-iteration slack of the pipelined recurrence is what
                hides the inter-pod latency behind the local SPMV
                (arXiv 1905.06850's global-reduction pipelining, mapped
                onto XLA's dataflow schedule).

Every reducer built here also carries an ``array`` attribute — the same
strategy applied to an arbitrary (stacked) array instead of the three
scalars. The depth-l pipelined methods (``core.iteration.
make_deep_pipecg_core``) reduce one packed Gram matrix per *l* iterations
through it; for ``separate``/``packed`` that is a single psum (there is
nothing to split once the partials are one array), for ``h4`` the same
two-stage hierarchy.

New strategies (e.g. a delayed/asynchronous reduction) plug in via
``register_reducer`` without touching the solver loop; factories flagged
``needs_subaxis = True`` (like ``h4``) are handed the full tuple of mesh
axis names and require a 2-D mesh (``make_solver_mesh(n, sub=...)``).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Reducer",
    "make_reducer",
    "register_reducer",
    "reducer_names",
    "reducer_needs_subaxis",
]

# A Reducer maps the three local dot partials to the three global dots.
# Reducers built by make_reducer additionally expose ``.array``:
# an (arbitrary-shaped) array of local partials -> globally reduced array.
Reducer = Callable[[jax.Array, jax.Array, jax.Array], Tuple[jax.Array, jax.Array, jax.Array]]


def _local(g, d, nn):
    return g, d, nn


_local.array = lambda a: a


def _separate(axis) -> Reducer:
    def reduce(g, d, nn):
        return (
            jax.lax.psum(g, axis),
            jax.lax.psum(d, axis),
            jax.lax.psum(nn, axis),
        )

    reduce.array = lambda a: jax.lax.psum(a, axis)
    return reduce


def _packed(axis) -> Reducer:
    def reduce(g, d, nn):
        packed = jax.lax.psum(jnp.stack([g, d, nn]), axis)
        return packed[0], packed[1], packed[2]

    reduce.array = lambda a: jax.lax.psum(a, axis)
    return reduce


def _hierarchical(axes) -> Reducer:
    if not isinstance(axes, (tuple, list)) or len(axes) != 2:
        raise ValueError(
            "reduction strategy 'h4' needs a 2-D mesh: pass the (pod, sub) "
            f"axis-name tuple (build one via make_solver_mesh(n, sub=...)), got {axes!r}"
        )
    pod_axis, sub_axis = axes

    def _two_stage(a):
        # stage 1: fast intra-pod reduction; stage 2: the one inter-pod
        # collective, whose result PIPECG consumes an iteration later
        return jax.lax.psum(jax.lax.psum(a, sub_axis), pod_axis)

    def reduce(g, d, nn):
        packed = _two_stage(jnp.stack([g, d, nn]))
        return packed[0], packed[1], packed[2]

    reduce.array = _two_stage
    return reduce


_hierarchical.needs_subaxis = True


# factory(axis) -> Reducer; axis is None for strategies that need no mesh,
# a mesh-axis name (or tuple of names) otherwise. ``needs_subaxis``
# factories are handed the full (pod, sub) axis-name tuple.
_REDUCERS: Dict[str, Callable[[Optional[str]], Reducer]] = {
    "local": lambda axis: _local,
    "separate": lambda axis: _separate(axis),
    "packed": lambda axis: _packed(axis),
    "h4": lambda axes: _hierarchical(axes),
}
_REDUCERS["h4"].needs_subaxis = True


def register_reducer(
    name: str, factory: Callable[[Optional[str]], Reducer], *, overwrite: bool = False
) -> None:
    """Register a reduction strategy: ``factory(axis_name) -> Reducer``.

    The returned reducer should also expose ``.array`` (strategy applied
    to one stacked array) so the depth-l pipelined methods can use it;
    flag the factory ``needs_subaxis = True`` when it requires the 2-D
    (pod, sub) mesh axis tuple. Raises ValueError if ``name`` is already
    registered, unless ``overwrite=True`` — silent replacement hides
    plug-in clashes.
    """
    if name in _REDUCERS and not overwrite:
        raise ValueError(
            f"reduction strategy {name!r} already registered; pass "
            f"overwrite=True to replace it"
        )
    _REDUCERS[name] = factory


def reducer_names() -> Tuple[str, ...]:
    return tuple(sorted(_REDUCERS))


def reducer_needs_subaxis(strategy: str) -> bool:
    """True if ``strategy`` requires a 2-D (pod, sub) mesh (e.g. "h4")."""
    if strategy not in _REDUCERS:
        raise ValueError(f"unknown reduction strategy {strategy!r}; have {reducer_names()}")
    return bool(getattr(_REDUCERS[strategy], "needs_subaxis", False))


def make_reducer(strategy: str, axis=None) -> Reducer:
    """Build the Reducer for ``strategy`` over mesh axis (or axes) ``axis``."""
    if strategy not in _REDUCERS:
        raise ValueError(f"unknown reduction strategy {strategy!r}; have {reducer_names()}")
    if strategy != "local" and axis is None:
        raise ValueError(f"reduction strategy {strategy!r} needs a mesh axis name")
    return _REDUCERS[strategy](axis)
