"""Reduction strategies for the PIPECG dot products.

One iteration of PIPECG produces three scalar partials — gamma = (r, u),
delta = (w, u) and ||u||^2 = (u, u). *How* those partials become global
scalars is the axis along which the paper's hybrid methods differ, so it
is factored out as a strategy the shared iteration core is parameterized
over (``core.iteration.run_pipecg``):

``local``     — identity: the partials already are the global dots
                (single-device execution).
``separate``  — three independent ``psum`` collectives (Hybrid-PIPECG-1:
                the paper's three separate async copies, maximally
                overlappable but 3x the collective count).
``packed``    — the three partials stacked into ONE length-3 ``psum``
                (Hybrid-PIPECG-2/3: the paper's copy-shrinking trick
                applied to reduction latency, 3 collectives -> 1).

New strategies (e.g. a two-phase hierarchical reduction across pods, or a
delayed/asynchronous reduction) plug in via ``register_reducer`` without
touching the solver loop.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Reducer", "make_reducer", "register_reducer", "reducer_names"]

# A Reducer maps the three local dot partials to the three global dots.
Reducer = Callable[[jax.Array, jax.Array, jax.Array], Tuple[jax.Array, jax.Array, jax.Array]]


def _local(g, d, nn):
    return g, d, nn


def _separate(axis: str) -> Reducer:
    def reduce(g, d, nn):
        return (
            jax.lax.psum(g, axis),
            jax.lax.psum(d, axis),
            jax.lax.psum(nn, axis),
        )

    return reduce


def _packed(axis: str) -> Reducer:
    def reduce(g, d, nn):
        packed = jax.lax.psum(jnp.stack([g, d, nn]), axis)
        return packed[0], packed[1], packed[2]

    return reduce


# factory(axis) -> Reducer; axis is None for strategies that need no mesh
_REDUCERS: Dict[str, Callable[[Optional[str]], Reducer]] = {
    "local": lambda axis: _local,
    "separate": lambda axis: _separate(axis),
    "packed": lambda axis: _packed(axis),
}


def register_reducer(
    name: str, factory: Callable[[Optional[str]], Reducer], *, overwrite: bool = False
) -> None:
    """Register a reduction strategy: ``factory(axis_name) -> Reducer``.

    Raises ValueError if ``name`` is already registered, unless
    ``overwrite=True`` — silent replacement hides plug-in clashes.
    """
    if name in _REDUCERS and not overwrite:
        raise ValueError(
            f"reduction strategy {name!r} already registered; pass "
            f"overwrite=True to replace it"
        )
    _REDUCERS[name] = factory


def reducer_names() -> Tuple[str, ...]:
    return tuple(sorted(_REDUCERS))


def make_reducer(strategy: str, axis: Optional[str] = None) -> Reducer:
    """Build the Reducer for ``strategy`` over mesh axis ``axis``."""
    if strategy not in _REDUCERS:
        raise ValueError(f"unknown reduction strategy {strategy!r}; have {reducer_names()}")
    if strategy != "local" and axis is None:
        raise ValueError(f"reduction strategy {strategy!r} needs a mesh axis name")
    return _REDUCERS[strategy](axis)
