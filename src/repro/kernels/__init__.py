"""Pallas TPU kernels for the compute hot spots the paper optimizes.

fused_iter — the WHOLE PIPECG iteration: banded DIA SPMV + 8 VMAs +
             Jacobi PC + dot partials in one grid walk, so one iteration
             launches one kernel (Rupp et al., arXiv 1410.4054).
fused_vma  — PIPECG iteration core: 8 VMAs + Jacobi PC + dot partials,
             one HBM pass (paper §V-B kernel fusion, extended).
fused_dot  — gamma/delta/(u,u) in one pass (merged reductions).
spmv_dia   — banded/stencil SPMV (TPU-native replacement for CSR SPMV).
spmv_bell  — Block-ELLPACK SPMV for general sparsity.
fused_adam — the fusion idea applied to the LM training substrate.
flash_attn — single-pass causal attention (online softmax in VMEM scratch);
             the fix for the memory-dominant roofline cells (§Perf).

Every kernel ships kernel.py (pallas_call + BlockSpec), ops.py (jit'd
public wrapper), ref.py (pure-jnp oracle); tests sweep shapes/dtypes with
interpret=True on CPU.
"""
from .flash_attn import flash_attention, flash_attention_ref
from .fused_adam import fused_adamw, fused_adamw_ref
from .fused_dot import fused_dots, fused_dots_ref
from .fused_iter import fused_iter_ref, fused_iter_step, fused_iter_tile
from .fused_vma import fused_vma_dots, fused_vma_dots_ref
from .spmv_bell import spmv_bell_pallas, spmv_bell_ref
from .spmv_dia import spmv_dia_pallas, spmv_dia_ref

__all__ = [
    "flash_attention",
    "flash_attention_ref",
    "fused_adamw",
    "fused_adamw_ref",
    "fused_dots",
    "fused_dots_ref",
    "fused_iter_ref",
    "fused_iter_step",
    "fused_iter_tile",
    "fused_vma_dots",
    "fused_vma_dots_ref",
    "spmv_bell_pallas",
    "spmv_bell_ref",
    "spmv_dia_pallas",
    "spmv_dia_ref",
]
