"""Causal flash attention kernel (Pallas TPU).

The single-pass answer to the memory-dominant roofline cells
(EXPERIMENTS.md §Perf): HLO-level attention — even blocked — materializes
probability tiles in HBM because XLA loop carries live in HBM; this kernel
keeps the online-softmax state (m, l) and the output accumulator in VMEM
scratch across the KV-tile grid steps, so HBM traffic is exactly
Q + K + V + O (one read each, one write).

Grid: (batch, q_heads, q_tiles, kv_tiles); the kv axis is the innermost
(sequential) dimension, scratch persists across it, and the output tile is
written once at the last kv step. GQA is expressed in the k/v BlockSpec
index maps (head h reads kv-head h // n_rep). Causality skips nothing
structurally (masked tiles still run) — block-level skipping is a TPU
grid-pruning option noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 128
DEFAULT_KV_TILE = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, kv_tiles, q_tile, kv_tile, sm_scale, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * sm_scale  # (q_tile, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)             # (kv_tile, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = q @ k.T  # (q_tile, kv_tile)
    if causal:
        q_pos = qi * q_tile + jax.lax.broadcasted_iota(jnp.int32, (q_tile, kv_tile), 0)
        k_pos = ki * kv_tile + jax.lax.broadcasted_iota(jnp.int32, (q_tile, kv_tile), 1)
        s = jnp.where(k_pos <= q_pos, s, -1e30)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + p.sum(axis=-1)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v

    @pl.when(ki == kv_tiles - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_padded(q, k, v, *, n_rep: int, q_tile: int, kv_tile: int,
                           causal: bool, interpret: bool):
    """q (B,Tq,H,hd), k/v (B,Tk,KV,hd); Tq % q_tile == 0, Tk % kv_tile == 0."""
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    q_tiles = Tq // q_tile
    kv_tiles = Tk // kv_tile
    sm_scale = 1.0 / (hd ** 0.5)

    kern = functools.partial(
        _kernel, kv_tiles=kv_tiles, q_tile=q_tile, kv_tile=kv_tile,
        sm_scale=sm_scale, causal=causal,
    )
    from jax.experimental.pallas import tpu as pltpu

    fn = pl.pallas_call(
        kern,
        grid=(B, H, q_tiles, kv_tiles),
        in_specs=[
            pl.BlockSpec((1, q_tile, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, kv_tile, 1, hd), lambda b, h, qi, ki: (b, ki, h // n_rep, 0)),
            pl.BlockSpec((1, kv_tile, 1, hd), lambda b, h, qi, ki: (b, ki, h // n_rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_tile, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_tile,), jnp.float32),
            pltpu.VMEM((q_tile,), jnp.float32),
            pltpu.VMEM((q_tile, hd), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(q, k, v)
