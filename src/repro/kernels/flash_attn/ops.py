"""Public wrapper for the flash attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from ..common import interpret_default
from .kernel import DEFAULT_KV_TILE, DEFAULT_Q_TILE, flash_attention_padded

__all__ = ["flash_attention"]


@partial(jax.jit, static_argnames=("causal", "q_tile", "kv_tile", "interpret"))
def _flash(q, k, v, causal, q_tile, kv_tile, interpret):
    n_rep = q.shape[2] // k.shape[2]
    return flash_attention_padded(
        q, k, v, n_rep=n_rep, q_tile=q_tile, kv_tile=kv_tile, causal=causal, interpret=interpret
    )


def flash_attention(q, k, v, causal: bool = True, q_tile: int | None = None,
                    kv_tile: int | None = None, interpret: bool | None = None):
    """Single-pass causal attention. q (B,Tq,H,hd); k/v (B,Tk,KV,hd).

    Tq/Tk must be divisible by the tile sizes (tiles auto-shrink to the
    sequence length for short inputs).
    """
    if interpret is None:
        interpret = interpret_default()
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    qt = min(q_tile or DEFAULT_Q_TILE, Tq)
    kt = min(kv_tile or DEFAULT_KV_TILE, Tk)
    if Tq % qt or Tk % kt:
        raise ValueError(f"Tq={Tq} % {qt} or Tk={Tk} % {kt} != 0")
    return _flash(q, k, v, causal, qt, kt, interpret)
