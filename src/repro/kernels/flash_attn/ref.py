"""Oracle for causal flash attention: plain softmax attention.

q (B, Tq, H, hd); k/v (B, Tk, KV, hd); GQA via n_rep = H // KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True):
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    n_rep = H // KV
    qg = q.reshape(B, Tq, KV, n_rep, hd)
    s = jnp.einsum("bqgrh,bkgh->bgrqk", qg, k, preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(hd).astype(jnp.float32)
    if causal:
        mask = jnp.arange(Tk)[None, :] <= jnp.arange(Tq)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgh->bqgrh", p.astype(q.dtype), v)
    return o.reshape(B, Tq, H, hd)
