from .ops import spmv_dia_pallas
from .ref import spmv_dia_ref

__all__ = ["spmv_dia_pallas", "spmv_dia_ref"]
