"""Banded (DIA) SPMV kernel — the paper's SPMV hot spot, TPU-adapted.

GPU SPMV in the paper is cuSPARSE CSR. CSR's ragged rows are hostile to the
TPU vector unit, so the TPU-native banded form is used instead: each stencil
diagonal is a dense vector and SPMV is a sum of statically-shifted
elementwise multiplies (pure VPU work, no gathers — this is the
hardware-adaptation noted in DESIGN.md).

Tiling: the grid walks y in 1-D tiles of TILE elements. The x operand is
passed three times with neighbor index maps (left / center / right block),
so every static shift within ``bandwidth <= TILE`` reads from the
concatenated 3-tile window held in VMEM. Diagonal data blocks are (n_diags,
TILE) VMEM tiles.

Boundary correctness relies on the DIA convention that ``data[j, i] = 0``
whenever column ``i + off[j]`` falls outside [0, n) — clamped neighbor
blocks at the edges are multiplied by those zeros.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(offsets, tile, dat_ref, xl_ref, xc_ref, xr_ref, y_o):
    xwin = jnp.concatenate([xl_ref[...], xc_ref[...], xr_ref[...]])
    acc = jnp.zeros((tile,), jnp.float32)
    for j, o in enumerate(offsets):
        seg = jax.lax.dynamic_slice(xwin, (tile + o,), (tile,))
        acc = acc + dat_ref[j, :].astype(jnp.float32) * seg.astype(jnp.float32)
    y_o[...] = acc.astype(y_o.dtype)


def spmv_dia_padded(data, offsets: tuple[int, ...], x, *, tile: int, interpret: bool,
                    out_dtype=None):
    """data (k, n_pad), x (n_pad,) with n_pad % tile == 0; bandwidth <= tile.

    ``out_dtype`` decouples output from storage precision: the kernel
    always accumulates in f32, so bf16 ``data``/``x`` with
    ``out_dtype=f32`` is the mixed-precision (bf16-storage /
    f32-accumulate) SPMV.
    """
    n_pad = x.shape[0]
    assert n_pad % tile == 0
    tiles = n_pad // tile
    last = tiles - 1

    kern = partial(_kernel, offsets, tile)
    fn = pl.pallas_call(
        kern,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((len(offsets), tile), lambda i: (0, i)),
            pl.BlockSpec((tile,), lambda i: (jnp.maximum(i - 1, 0),)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (jnp.minimum(i + 1, last),)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), out_dtype or x.dtype),
        interpret=interpret,
    )
    return fn(data, x, x, x)
