"""Public wrapper for the banded SPMV kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...sparse.formats import DIAMatrix
from ..common import LANE, ceil_to, interpret_default, pad1d
from .kernel import spmv_dia_padded

__all__ = ["spmv_dia_pallas"]

_DEFAULT_TILE = 4096


@partial(jax.jit, static_argnames=("offsets", "tile", "interpret", "out_dtype"))
def _spmv(data, offsets, x, tile: int, interpret: bool, out_dtype):
    n = x.shape[0]
    n_pad = ceil_to(n, tile)
    xp = pad1d(x, n_pad)
    dp = jnp.pad(data, ((0, 0), (0, n_pad - n)))
    y = spmv_dia_padded(dp, offsets, xp, tile=tile, interpret=interpret, out_dtype=out_dtype)
    return y[:n]


def spmv_dia_pallas(A: DIAMatrix, x: jax.Array, tile: int | None = None,
                    interpret: bool | None = None, out_dtype=None):
    """y = A @ x for a DIA matrix via the Pallas banded kernel.

    ``tile`` must be >= the matrix bandwidth (halo lives in the neighbor
    blocks); it is auto-raised (LANE-aligned) when needed. ``out_dtype``
    (default: x.dtype) lets bf16-storage inputs emit the f32-accumulated
    result without a round trip through bf16.
    """
    if interpret is None:
        interpret = interpret_default()
    bw = A.bandwidth
    t = tile or _DEFAULT_TILE
    t = max(t, ceil_to(bw + 1, LANE))
    out_dtype = jnp.dtype(out_dtype) if out_dtype is not None else None
    return _spmv(A.data, A.offsets, x, t, interpret, out_dtype)
