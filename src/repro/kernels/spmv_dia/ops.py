"""Public wrapper for the banded SPMV kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...sparse.formats import DIAMatrix
from ..common import LANE, ceil_to, interpret_default, pad1d
from .kernel import spmv_dia_padded

__all__ = ["spmv_dia_pallas"]

_DEFAULT_TILE = 4096


@partial(jax.jit, static_argnames=("offsets", "tile", "interpret"))
def _spmv(data, offsets, x, tile: int, interpret: bool):
    n = x.shape[0]
    n_pad = ceil_to(n, tile)
    xp = pad1d(x, n_pad)
    dp = jnp.pad(data, ((0, 0), (0, n_pad - n)))
    y = spmv_dia_padded(dp, offsets, xp, tile=tile, interpret=interpret)
    return y[:n]


def spmv_dia_pallas(A: DIAMatrix, x: jax.Array, tile: int | None = None, interpret: bool | None = None):
    """y = A @ x for a DIA matrix via the Pallas banded kernel.

    ``tile`` must be >= the matrix bandwidth (halo lives in the neighbor
    blocks); it is auto-raised (LANE-aligned) when needed.
    """
    if interpret is None:
        interpret = interpret_default()
    bw = A.bandwidth
    t = tile or _DEFAULT_TILE
    t = max(t, ceil_to(bw + 1, LANE))
    return _spmv(A.data, A.offsets, x, t, interpret)
