"""Oracle for banded (DIA) SPMV: y[i] = sum_j data[j,i] * x[i+off[j]]."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_dia_ref(data: jax.Array, offsets: tuple[int, ...], x: jax.Array) -> jax.Array:
    n = x.shape[0]
    y = jnp.zeros_like(x)
    for j, o in enumerate(offsets):
        if o == 0:
            xs = x
        elif o > 0:
            xs = jnp.concatenate([x[o:], jnp.zeros((o,), x.dtype)])
        else:
            xs = jnp.concatenate([jnp.zeros((-o,), x.dtype), x[:o]])
        y = y + data[j] * xs
    return y
