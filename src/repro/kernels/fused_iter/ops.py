"""Public wrapper for the whole-iteration fused PIPECG kernel.

Unlike ``fused_vma``, this wrapper does NOT pad per call: operands must
arrive pre-padded to a multiple of ``tile`` (the solver pads once per
solve — see ``core.pipecg``'s padded execution path). ``trace_count()``
counts how many times the kernel program has been (re)built, the
launch-census hook the benchmarks record.
"""
from __future__ import annotations

from functools import partial

import jax

from ..common import LANE, ceil_to, interpret_default
from .kernel import TILE, fused_iter_padded

__all__ = ["fused_iter_step", "fused_iter_tile", "trace_count"]

_TRACES = 0


def trace_count() -> int:
    """Times the fused-iteration kernel program has been traced/built."""
    return _TRACES


def fused_iter_tile(bandwidth: int, tile: int | None = None) -> int:
    """The row-tile the kernel will use: LANE-aligned, >= bandwidth + 1."""
    t = tile or TILE
    return max(t, ceil_to(bandwidth + 1, LANE))


@partial(jax.jit, static_argnames=("offsets", "tile", "interpret"))
def _step(data, z, q, s, p, x, r, u, w, m, inv_diag, alpha, beta,
          offsets, tile: int, interpret: bool):
    global _TRACES
    _TRACES += 1  # runs at trace time only
    outs = fused_iter_padded(
        data, offsets, (z, q, s, p, x, r, u, w, m), inv_diag, alpha, beta,
        tile=tile, interpret=interpret,
    )
    dots = outs[9][:, :3].sum(axis=0)
    return tuple(outs[:9]) + (dots,)


def fused_iter_step(data, offsets, z, q, s, p, x, r, u, w, m, inv_diag,
                    alpha, beta, tile: int, interpret: bool | None = None):
    """One fused PIPECG iteration: SPMV + 8 VMAs + Jacobi PC + dot partials.

    All vector operands and ``data``'s row length must be pre-padded to a
    multiple of ``tile`` (>= bandwidth, LANE-aligned — see
    :func:`fused_iter_tile`). Returns (z', q', s', p', x', r', u', w', m',
    dots) with dots = float32 [ (r',u'), (w',u'), (u',u') ].
    """
    if interpret is None:
        interpret = interpret_default()
    n_pad = z.shape[0]
    if n_pad % tile or tile % LANE:
        raise ValueError(f"operands must be pre-padded: n_pad={n_pad}, tile={tile}")
    return _step(data, z, q, s, p, x, r, u, w, m, inv_diag, alpha, beta,
                 offsets, tile, interpret)
