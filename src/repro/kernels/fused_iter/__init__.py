from .kernel import TILE, fused_iter_padded
from .ops import fused_iter_step, fused_iter_tile, trace_count
from .ref import fused_iter_ref

__all__ = [
    "TILE",
    "fused_iter_padded",
    "fused_iter_ref",
    "fused_iter_step",
    "fused_iter_tile",
    "trace_count",
]
