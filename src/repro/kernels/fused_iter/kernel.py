"""Whole-iteration fused PIPECG kernel (Pallas TPU).

Rupp et al. (arXiv 1410.4054) show that pipelined solvers win on
accelerators when the *entire* iteration is fused, not just the SPMV.
This kernel is that step beyond ``fused_vma``: one grid walk over row
tiles computes, per tile,

    SPMV   n = A m           (banded DIA, 3-window shifted reads — the
                              ``spmv_dia`` idiom)
    VMAs   z q s p x r u w   (the 8 recurrences of Alg. 2 lines 10-17)
    PC     m' = inv_diag * w (Jacobi, line 21)
    dots   (r,u) (w,u) (u,u) partials (lines 18-20)

so one PIPECG iteration launches exactly ONE kernel. The SPMV is moved
from the end of iteration i-1 to the start of iteration i — identical
math (n is A m of the *previous* m either way), but now m is a fully
materialized input and the cross-tile halo reads need no intra-kernel
synchronization: tile i reads the (i-1, i, i+1) window of m via three
neighbor-indexed BlockSpecs, exactly like ``spmv_dia``.

Per-element HBM traffic (f32): reads z q s p x r u w m inv + k diag
rows, writes z q s p x r u w m — (10 + k) * 4 B in, 9 * 4 B out, one
round trip per vector per iteration.

Boundary correctness relies on the DIA convention that ``data[j, i] = 0``
whenever column ``i + off[j]`` falls outside [0, n): the zero-padded
tail (n..n_pad) therefore stays zero through every recurrence, which is
what lets the solver loop run entirely on padded views.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import LANE

TILE = 4096  # 1-D row tile; must be >= matrix bandwidth (halo = 1 tile)


def _kernel(
    offsets, tile,
    alpha_ref, beta_ref,
    dat_ref, ml_ref, mc_ref, mr_ref,
    z_ref, q_ref, s_ref, p_ref, x_ref, r_ref, u_ref, w_ref, inv_ref,
    z_o, q_o, s_o, p_o, x_o, r_o, u_o, w_o, m_o, dots_o,
):
    dtype = z_ref.dtype
    alpha = alpha_ref[0].astype(dtype)
    beta = beta_ref[0].astype(dtype)

    # --- SPMV n = A m on the concatenated 3-tile window (f32 accumulate) ---
    mwin = jnp.concatenate([ml_ref[...], mc_ref[...], mr_ref[...]])
    acc = jnp.zeros((tile,), jnp.float32)
    for j, o in enumerate(offsets):
        seg = jax.lax.dynamic_slice(mwin, (tile + o,), (tile,))
        acc = acc + dat_ref[j, :].astype(jnp.float32) * seg.astype(jnp.float32)
    n_v = acc.astype(dtype)

    # --- the 8 VMAs + Jacobi PC (the pipecg_vma_core recurrence) ---
    m_v = mc_ref[...]
    w_v = w_ref[...]
    u_v = u_ref[...]

    z_v = n_v + beta * z_ref[...]
    q_v = m_v + beta * q_ref[...]
    s_v = w_v + beta * s_ref[...]
    p_v = u_v + beta * p_ref[...]

    x_o[...] = x_ref[...] + alpha * p_v
    r_v = r_ref[...] - alpha * s_v
    u_n = u_v - alpha * q_v
    w_n = w_v - alpha * z_v
    m_n = inv_ref[...] * w_n

    z_o[...] = z_v
    q_o[...] = q_v
    s_o[...] = s_v
    p_o[...] = p_v
    r_o[...] = r_v
    u_o[...] = u_n
    w_o[...] = w_n
    m_o[...] = m_n

    # --- per-tile dot partials on the vectors just produced ---
    rf = r_v.astype(jnp.float32)
    uf = u_n.astype(jnp.float32)
    wf = w_n.astype(jnp.float32)
    part = jnp.stack([jnp.sum(rf * uf), jnp.sum(wf * uf), jnp.sum(uf * uf)])
    dots_o[...] = jnp.pad(part[None, :], ((0, 0), (0, LANE - 3)))


def fused_iter_padded(data, offsets, vecs, inv_diag, alpha, beta, *, tile: int, interpret: bool):
    """One fused PIPECG iteration on padded operands.

    data (k, n_pad) zero-padded DIA diagonals; vecs = (z, q, s, p, x, r,
    u, w, m) each (n_pad,) with n_pad % tile == 0; bandwidth <= tile.
    Returns 9 updated vectors (z q s p x r u w m) + per-tile dot partials
    (tiles, LANE).
    """
    n_pad = vecs[0].shape[0]
    assert n_pad % tile == 0, (n_pad, tile)
    tiles = n_pad // tile
    last = tiles - 1
    dtype = vecs[0].dtype

    z, q, s, p, x, r, u, w, m = vecs
    vec_spec = pl.BlockSpec((tile,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    out_shapes = [jax.ShapeDtypeStruct((n_pad,), dtype) for _ in range(9)]
    out_shapes.append(jax.ShapeDtypeStruct((tiles, LANE), jnp.float32))
    out_specs = [vec_spec] * 9 + [pl.BlockSpec((1, LANE), lambda i: (i, 0))]

    fn = pl.pallas_call(
        partial(_kernel, offsets, tile),
        grid=(tiles,),
        in_specs=[
            scalar_spec,                                            # alpha
            scalar_spec,                                            # beta
            pl.BlockSpec((len(offsets), tile), lambda i: (0, i)),   # diagonals
            pl.BlockSpec((tile,), lambda i: (jnp.maximum(i - 1, 0),)),  # m left
            pl.BlockSpec((tile,), lambda i: (i,)),                      # m center
            pl.BlockSpec((tile,), lambda i: (jnp.minimum(i + 1, last),)),  # m right
        ] + [vec_spec] * 9,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    beta = jnp.asarray(beta, jnp.float32).reshape(1)
    return fn(alpha, beta, data, m, m, m, z, q, s, p, x, r, u, w, inv_diag)
