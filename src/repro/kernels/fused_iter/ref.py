"""Oracle for the whole-iteration fused kernel.

Delegates to the two canonical implementations the rest of the repo
runs — ``spmv_dia_ref`` for n = A m and ``core.iteration.pipecg_vma_core``
for the recurrence — so the fused kernel is validated against exactly the
math of the unfused path (exact-recurrence parity, not a re-derivation).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..spmv_dia.ref import spmv_dia_ref


def fused_iter_ref(data, offsets, z, q, s, p, x, r, u, w, m, inv_diag, alpha, beta):
    """n = A m, then the canonical PIPECG recurrence on it.

    Same contract as the fused kernel: returns (z', q', s', p', x', r',
    u', w', m', (gamma, delta, ||u||^2)).
    """
    from ...core.iteration import pipecg_vma_core  # lazy: core imports kernels

    alpha = jnp.asarray(alpha, dtype=z.dtype)
    beta = jnp.asarray(beta, dtype=z.dtype)
    n_vec = spmv_dia_ref(data, offsets, m)
    return pipecg_vma_core(z, q, s, p, x, r, u, w, n_vec, m, inv_diag, alpha, beta)
