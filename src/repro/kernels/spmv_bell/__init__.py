from .ops import spmv_bell_pallas
from .ref import spmv_bell_ref

__all__ = ["spmv_bell_pallas", "spmv_bell_ref"]
