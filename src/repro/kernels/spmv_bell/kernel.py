"""Block-ELLPACK SPMV kernel (general sparsity on TPU).

ELLPACK pads every row to a fixed slot count R, giving a fully regular
(rows, R) layout — the TPU answer to CSR's ragged rows (DESIGN.md
§hardware-adaptation). The kernel tiles rows; the source vector x is held
whole in VMEM (one block) because slot columns may point anywhere. That
bounds this kernel to n <= ~2M f32 (8 MiB VMEM); larger operators should be
banded (spmv_dia) or row-partitioned across chips first, which is exactly
what the distributed solver does.

The gather ``x[cols]`` inside the kernel lowers to TPU dynamic-gather; on
CPU validation (interpret=True) it is a numpy-style take.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 512


def _kernel(cols_ref, vals_ref, x_ref, y_o):
    x = x_ref[...]
    gathered = x[cols_ref[...]]  # (tile, R) dynamic gather from VMEM
    acc = (vals_ref[...].astype(jnp.float32) * gathered.astype(jnp.float32)).sum(axis=1)
    y_o[...] = acc.astype(y_o.dtype)


def spmv_bell_padded(cols, vals, x, *, interpret: bool):
    n_rows = cols.shape[0]
    R = cols.shape[1]
    assert n_rows % TILE_ROWS == 0
    tiles = n_rows // TILE_ROWS
    n = x.shape[0]
    fn = pl.pallas_call(
        _kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, R), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, R), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_rows,), x.dtype),
        interpret=interpret,
    )
    return fn(cols, vals, x)
