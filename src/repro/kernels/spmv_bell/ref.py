"""Oracle for Block-ELLPACK SPMV: y[i] = sum_r vals[i,r] * x[cols[i,r]]."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_bell_ref(cols: jax.Array, vals: jax.Array, x: jax.Array) -> jax.Array:
    return (vals.astype(jnp.float32) * x[cols].astype(jnp.float32)).sum(axis=1).astype(x.dtype)
