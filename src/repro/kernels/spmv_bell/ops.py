"""Public wrapper for the Block-ELLPACK SPMV kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...sparse.formats import BellMatrix
from ..common import ceil_to, interpret_default, pad1d
from .kernel import TILE_ROWS, spmv_bell_padded

__all__ = ["spmv_bell_pallas"]

_VMEM_ROWS_LIMIT = 2 * 1024 * 1024  # x must fit VMEM


@partial(jax.jit, static_argnames=("interpret",))
def _spmv(cols, vals, x, interpret: bool):
    n = x.shape[0]
    rows_pad = ceil_to(n, TILE_ROWS)
    cp = jnp.pad(cols, ((0, rows_pad - n), (0, 0)))  # pad rows gather x[0] * 0
    vp = jnp.pad(vals, ((0, rows_pad - n), (0, 0)))
    y = spmv_bell_padded(cp, vp, x, interpret=interpret)
    return y[:n]


def spmv_bell_pallas(A: BellMatrix, x: jax.Array, interpret: bool | None = None):
    if interpret is None:
        interpret = interpret_default()
    if A.n > _VMEM_ROWS_LIMIT:
        raise ValueError(
            f"spmv_bell keeps x resident in VMEM; n={A.n} exceeds {_VMEM_ROWS_LIMIT}. "
            "Partition rows across chips (distributed solver) or use spmv_dia."
        )
    return _spmv(A.cols, A.vals, x, interpret)
