"""Oracle for the fused AdamW update (single flat parameter vector)."""
from __future__ import annotations

import jax.numpy as jnp


def fused_adamw_ref(p, g, m, v, lr, b1, b2, eps, wd, step):
    """step is the 1-based step count (float32)."""
    gf = g.astype(jnp.float32)
    mf = b1 * m + (1.0 - b1) * gf
    vf = b2 * v + (1.0 - b2) * gf * gf
    mhat = mf / (1.0 - b1**step)
    vhat = vf / (1.0 - b2**step)
    update = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
    return p_new, mf, vf
