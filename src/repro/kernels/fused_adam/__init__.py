from .ops import fused_adamw
from .ref import fused_adamw_ref

__all__ = ["fused_adamw", "fused_adamw_ref"]
