"""Public wrapper: fused AdamW over a flat parameter vector."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import LANE, as_2d, ceil_to, interpret_default, pad1d
from .kernel import TILE_ROWS, fused_adamw_padded

__all__ = ["fused_adamw"]


@partial(jax.jit, static_argnames=("interpret",))
def _adamw(p, g, m, v, lr, b1, b2, eps, wd, step, interpret: bool):
    n = p.shape[0]
    n_pad = ceil_to(n, TILE_ROWS * LANE)
    p2 = as_2d(pad1d(p, n_pad))
    g2 = as_2d(pad1d(g, n_pad))
    m2 = as_2d(pad1d(m, n_pad))
    v2 = as_2d(pad1d(v, n_pad))
    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step
    hyper = jnp.stack([lr, b1, b2, eps, wd, bc1, bc2]).astype(jnp.float32)
    p_n, m_n, v_n = fused_adamw_padded(hyper, p2, g2, m2, v2, interpret=interpret)
    flat = lambda a: a.reshape(-1)[:n]
    return flat(p_n), flat(m_n), flat(v_n)


def fused_adamw(p, g, m, v, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0, step=1.0, interpret: bool | None = None):
    """Single-pass AdamW. p/g any float dtype; m/v float32. step is 1-based."""
    if interpret is None:
        interpret = interpret_default()
    args = [jnp.asarray(a, jnp.float32) for a in (lr, b1, b2, eps, wd, step)]
    return _adamw(p, g, m, v, *args, interpret)
