"""Fused AdamW kernel — the paper's kernel-fusion idea applied to training.

An unfused AdamW is ~8 elementwise passes over 4 N-sized buffers; fused it
is a single pass (reads p,g,m,v; writes p,m,v), the same transformation the
paper performs on the PIPECG VMA pipeline. Optimizer state is kept in
float32 while parameters may be bf16 (mixed-precision master-in-f32 is a
separate policy in train/optimizer.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import LANE

TILE_ROWS = 32


def _kernel(h_ref, p_ref, g_ref, m_ref, v_ref, p_o, m_o, v_o):
    lr, b1, b2, eps, wd, bc1, bc2 = (h_ref[i] for i in range(7))
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    p = p_ref[...].astype(jnp.float32)
    upd = mhat / (jnp.sqrt(vhat) + eps) + wd * p
    p_o[...] = (p - lr * upd).astype(p_o.dtype)
    m_o[...] = m
    v_o[...] = v


def fused_adamw_padded(hyper, p, g, m, v, *, interpret: bool):
    """hyper = f32[7] = (lr, b1, b2, eps, wd, 1-b1^t, 1-b2^t); 2-D operands."""
    rows = p.shape[0]
    assert rows % TILE_ROWS == 0
    tiles = rows // TILE_ROWS
    vec = pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0))
    hyp = pl.BlockSpec((7,), lambda i: (0,))
    fn = pl.pallas_call(
        _kernel,
        grid=(tiles,),
        in_specs=[hyp, vec, vec, vec, vec],
        out_specs=[vec, vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(m.shape, jnp.float32),
            jax.ShapeDtypeStruct(v.shape, jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(hyper, p, g, m, v)
