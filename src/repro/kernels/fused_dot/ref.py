"""Oracle for the fused triple dot product (PIPECG lines 18-20)."""
from __future__ import annotations

import jax.numpy as jnp


def fused_dots_ref(r, u, w):
    rf, uf, wf = (a.astype(jnp.float32) for a in (r, u, w))
    return jnp.stack([jnp.sum(rf * uf), jnp.sum(wf * uf), jnp.sum(uf * uf)])
