from .ops import fused_dots
from .ref import fused_dots_ref

__all__ = ["fused_dots", "fused_dots_ref"]
