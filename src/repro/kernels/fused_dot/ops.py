"""Public wrapper for the fused triple dot product."""
from __future__ import annotations

from functools import partial

import jax

from ..common import LANE, as_2d, ceil_to, interpret_default, pad1d
from .kernel import TILE_ROWS, fused_dots_padded

__all__ = ["fused_dots"]


@partial(jax.jit, static_argnames=("interpret",))
def _fused(r, u, w, interpret: bool):
    n = r.shape[0]
    n_pad = ceil_to(n, TILE_ROWS * LANE)
    r2, u2, w2 = (as_2d(pad1d(v, n_pad)) for v in (r, u, w))
    parts = fused_dots_padded(r2, u2, w2, interpret=interpret)
    return parts[:, :3].sum(axis=0)


def fused_dots(r, u, w, interpret: bool | None = None):
    """float32 [ (r,u), (w,u), (u,u) ] in a single memory pass."""
    if interpret is None:
        interpret = interpret_default()
    return _fused(r, u, w, interpret)
