"""Fused triple-dot kernel: gamma=(r,u), delta=(w,u), uu=(u,u) in one pass.

Unfused, the three dots read 6N elements (u three times); fused they read
3N — the same merged-reads idea the paper applies to the CPU side (§V-B.2).
Per-tile partials are emitted to a (tiles, LANE) buffer; the wrapper sums
them (exact f32 tree-sum of tile partials).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import LANE

TILE_ROWS = 64


def _kernel(r_ref, u_ref, w_ref, dots_o):
    rf = r_ref[...].astype(jnp.float32)
    uf = u_ref[...].astype(jnp.float32)
    wf = w_ref[...].astype(jnp.float32)
    partial = jnp.stack([jnp.sum(rf * uf), jnp.sum(wf * uf), jnp.sum(uf * uf)])
    dots_o[...] = jnp.pad(partial[None, :], ((0, 0), (0, LANE - 3)))


def fused_dots_padded(r, u, w, *, interpret: bool):
    rows = r.shape[0]
    assert rows % TILE_ROWS == 0
    tiles = rows // TILE_ROWS
    vec_spec = pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0))
    fn = pl.pallas_call(
        _kernel,
        grid=(tiles,),
        in_specs=[vec_spec] * 3,
        out_specs=pl.BlockSpec((1, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles, LANE), jnp.float32),
        interpret=interpret,
    )
    return fn(r, u, w)
