"""Pure-jnp oracle for the fused PIPECG iteration core.

One PIPECG iteration's vector work (Alg. 2 lines 10-21 + dot partials):

    z = n + beta*z ; q = m + beta*q ; s = w + beta*s ; p = u + beta*p
    x += alpha*p ; r -= alpha*s ; u -= alpha*q ; w -= alpha*z
    m = inv_diag * w                       (Jacobi PC, fused)
    dots = [ (r,u), (w,u), (u,u) ]         (float32 accumulation)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_vma_dots_ref(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta):
    alpha = jnp.asarray(alpha, dtype=z.dtype)
    beta = jnp.asarray(beta, dtype=z.dtype)
    z = n + beta * z
    q = m + beta * q
    s = w + beta * s
    p = u + beta * p
    x = x + alpha * p
    r = r - alpha * s
    u = u - alpha * q
    w = w - alpha * z
    m = inv_diag * w
    rf, uf, wf = (a.astype(jnp.float32) for a in (r, u, w))
    dots = jnp.stack([jnp.sum(rf * uf), jnp.sum(wf * uf), jnp.sum(uf * uf)])
    return z, q, s, p, x, r, u, w, m, dots
