"""Pure-jnp oracle for the fused PIPECG iteration core.

Delegates to the ONE canonical recurrence (``core.iteration.
pipecg_vma_core``) so the kernel is validated against exactly the math the
solvers run; this module only adapts the dot partials to the kernel's
stacked-float32 output contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_vma_dots_ref(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta):
    from ...core.iteration import pipecg_vma_core

    alpha = jnp.asarray(alpha, dtype=z.dtype)
    beta = jnp.asarray(beta, dtype=z.dtype)
    *vecs, (g, d, nn) = pipecg_vma_core(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta)
    return (*vecs, jnp.stack([g, d, nn]).astype(jnp.float32))
