"""Fused PIPECG iteration-core kernel (Pallas TPU).

The paper's §V-B fuses the eight VMA updates and the Jacobi PC into one GPU
kernel so every vector makes a single HBM round trip. This kernel goes one
step further and also emits the three dot-product partials (gamma, delta,
(u,u)) for the tile, because they read exactly the vectors the update just
produced — on TPU that turns the whole iteration core into one
HBM-bandwidth-bound pass:

    reads : z q s p x r u w n m inv_diag   (11 N)
    writes: z q s p x r u w m              (9 N)

versus 8 separate AXPYs + PC + 3 dots = 27 N reads + 9 N writes unfused.

Layout: vectors are zero-padded to a multiple of (TILE_ROWS*LANE) and viewed
as (rows, 128); the grid walks row-tiles; per-tile dot partials land in a
(tiles, 128) buffer summed by the wrapper (padding contributes zeros).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import LANE

TILE_ROWS = 32  # (32, 128) f32 tile = 16 KiB per operand per grid step


def _kernel(
    alpha_ref, beta_ref,
    z_ref, q_ref, s_ref, p_ref, x_ref, r_ref, u_ref, w_ref, n_ref, m_ref, inv_ref,
    z_o, q_o, s_o, p_o, x_o, r_o, u_o, w_o, m_o, dots_o,
):
    dtype = z_ref.dtype
    alpha = alpha_ref[0].astype(dtype)
    beta = beta_ref[0].astype(dtype)

    n_v = n_ref[...]
    m_v = m_ref[...]
    w_v = w_ref[...]
    u_v = u_ref[...]

    z_v = n_v + beta * z_ref[...]
    q_v = m_v + beta * q_ref[...]
    s_v = w_v + beta * s_ref[...]
    p_v = u_v + beta * p_ref[...]

    x_o[...] = x_ref[...] + alpha * p_v
    r_v = r_ref[...] - alpha * s_v
    u_n = u_v - alpha * q_v
    w_n = w_v - alpha * z_v
    m_n = inv_ref[...] * w_n

    z_o[...] = z_v
    q_o[...] = q_v
    s_o[...] = s_v
    p_o[...] = p_v
    r_o[...] = r_v
    u_o[...] = u_n
    w_o[...] = w_n
    m_o[...] = m_n

    rf = r_v.astype(jnp.float32)
    uf = u_n.astype(jnp.float32)
    wf = w_n.astype(jnp.float32)
    partial = jnp.stack([jnp.sum(rf * uf), jnp.sum(wf * uf), jnp.sum(uf * uf)])
    dots_o[...] = jnp.pad(partial[None, :], ((0, 0), (0, LANE - 3)))


def fused_vma_dots_padded(vecs, inv_diag, alpha, beta, *, interpret: bool):
    """Run the kernel on already-padded 2-D (rows, LANE) views.

    vecs = (z, q, s, p, x, r, u, w, n, m); returns 9 updated views +
    per-tile dot partials (tiles, LANE).
    """
    rows = vecs[0].shape[0]
    assert rows % TILE_ROWS == 0, (rows, TILE_ROWS)
    tiles = rows // TILE_ROWS
    dtype = vecs[0].dtype

    vec_spec = pl.BlockSpec((TILE_ROWS, LANE), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))

    out_shapes = [jax.ShapeDtypeStruct((rows, LANE), dtype) for _ in range(9)]
    out_shapes.append(jax.ShapeDtypeStruct((tiles, LANE), jnp.float32))
    out_specs = [vec_spec] * 9 + [pl.BlockSpec((1, LANE), lambda i: (i, 0))]

    fn = pl.pallas_call(
        _kernel,
        grid=(tiles,),
        in_specs=[scalar_spec, scalar_spec] + [vec_spec] * 11,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    beta = jnp.asarray(beta, jnp.float32).reshape(1)
    return fn(alpha, beta, *vecs, inv_diag)
