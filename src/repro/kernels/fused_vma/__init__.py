from .ops import fused_vma_dots
from .ref import fused_vma_dots_ref

__all__ = ["fused_vma_dots", "fused_vma_dots_ref"]
