"""Public wrapper for the fused PIPECG iteration core.

Padding contract: the wrapper accepts any length and zero-pads to the
(TILE_ROWS * LANE) tile grid — but ``pad1d`` and the trailing un-pad
slice are emitted ONLY when the inputs are misaligned. The solver's
padded execution path (``core.pipecg``) pads every vector once per
*solve* to this alignment, so inside the iteration hot loop all ten
per-call pads and nine un-pad slices vanish and the only per-iteration
work left is the kernel launch plus free (view-only) reshapes. Callers
that cannot pre-align still get the correct, if slower, pad-per-call
behavior.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import LANE, as_2d, ceil_to, interpret_default, pad1d
from .kernel import TILE_ROWS, fused_vma_dots_padded

__all__ = ["fused_vma_dots"]


@partial(jax.jit, static_argnames=("interpret",))
def _fused(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta, interpret: bool):
    n_elems = z.shape[0]
    n_pad = ceil_to(n_elems, TILE_ROWS * LANE)
    aligned = n_pad == n_elems  # pre-padded caller: no pads, no un-pad slices
    vecs = tuple(as_2d(pad1d(v, n_pad)) for v in (z, q, s, p, x, r, u, w, n, m))
    inv2 = as_2d(pad1d(inv_diag, n_pad))
    outs = fused_vma_dots_padded(vecs, inv2, alpha, beta, interpret=interpret)
    if aligned:
        news = tuple(o.reshape(-1) for o in outs[:9])
    else:
        news = tuple(o.reshape(-1)[:n_elems] for o in outs[:9])
    dots = outs[9][:, :3].sum(axis=0)
    return news + (dots,)


def fused_vma_dots(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta, interpret: bool | None = None):
    """Fused 8-VMA + Jacobi-PC + dot-partials pass (PIPECG lines 10-21).

    Returns (z', q', s', p', x', r', u', w', m', dots) where
    dots = float32 [ (r',u'), (w',u'), (u',u') ].
    """
    if interpret is None:
        interpret = interpret_default()
    outs = _fused(z, q, s, p, x, r, u, w, n, m, inv_diag, alpha, beta, interpret)
    return outs
