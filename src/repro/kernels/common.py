"""Shared Pallas kernel utilities.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with ``interpret=True``, which executes the kernel body in
Python. ``interpret_default()`` picks the right mode for the current
backend; tests may force it via ``FORCE_INTERPRET``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128        # TPU vector lane width
SUBLANE = 8       # float32 sublane count; (8, 128) is the native f32 tile

# Test hook: None -> auto (interpret on CPU, compiled on TPU).
FORCE_INTERPRET: bool | None = None


def interpret_default() -> bool:
    if FORCE_INTERPRET is not None:
        return FORCE_INTERPRET
    return jax.default_backend() != "tpu"


def ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad1d(x: jax.Array, n_pad: int) -> jax.Array:
    """Zero-pad a 1-D array to length n_pad."""
    n = x.shape[0]
    if n == n_pad:
        return x
    return jnp.pad(x, (0, n_pad - n))


def as_2d(x: jax.Array, lane: int = LANE) -> jax.Array:
    """(n_pad,) -> (n_pad // lane, lane) view for TPU-native tiling."""
    return x.reshape(-1, lane)
