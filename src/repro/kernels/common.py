"""Shared Pallas kernel utilities.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with ``interpret=True``, which executes the kernel body in
Python. ``interpret_default()`` picks the right mode for the current
backend; tests may force it via ``FORCE_INTERPRET``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128        # TPU vector lane width
SUBLANE = 8       # float32 sublane count; (8, 128) is the native f32 tile

# Test hook: None -> auto (interpret on CPU, compiled on TPU).
FORCE_INTERPRET: bool | None = None


def interpret_default() -> bool:
    if FORCE_INTERPRET is not None:
        return FORCE_INTERPRET
    return jax.default_backend() != "tpu"


def ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad1d(x: jax.Array, n_pad: int) -> jax.Array:
    """Zero-pad a 1-D array to length n_pad."""
    n = x.shape[0]
    if n == n_pad:
        return x
    return jnp.pad(x, (0, n_pad - n))


def as_2d(x: jax.Array, lane: int = LANE) -> jax.Array:
    """(n_pad,) -> (n_pad // lane, lane) view for TPU-native tiling."""
    return x.reshape(-1, lane)


# ---------------------------------------------------------------------------
# jaxpr census — count kernel launches (and pad traffic) per program region
# ---------------------------------------------------------------------------

def _sub_jaxprs(params):
    from jax import core as jcore

    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            if isinstance(item, jcore.ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, jcore.Jaxpr):
                yield item


def count_primitive(jaxpr, name: str, *, into_kernels: bool = True) -> int:
    """Occurrences of primitive ``name`` in a jaxpr, recursing into
    sub-jaxprs (pjit bodies, while cond/body, cond branches, ...).

    ``into_kernels=False`` stops recursion at ``pallas_call`` boundaries:
    ops inside a kernel body run on-chip per tile, so e.g. a ``pad``
    there is not per-iteration HBM traffic and should not count against
    a "no padding in the hot loop" invariant.
    """
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            total += 1
        if not into_kernels and eqn.primitive.name == "pallas_call":
            continue
        for sub in _sub_jaxprs(eqn.params):
            total += count_primitive(sub, name, into_kernels=into_kernels)
    return total


def while_body_jaxpr(jaxpr):
    """The body jaxpr of the first ``while`` found (recursively), or None.

    For the solver loops this is the per-iteration program region — the
    thing whose kernel-launch count the fusion work drives to 1.
    """
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            return eqn.params["body_jaxpr"].jaxpr
        for sub in _sub_jaxprs(eqn.params):
            found = while_body_jaxpr(sub)
            if found is not None:
                return found
    return None


def launches_per_iteration(fn, *args, primitive: str = "pallas_call") -> int:
    """Count ``primitive`` occurrences inside ``fn``'s solver-loop body.

    Traces ``fn(*args)`` (no execution) and censuses the first while
    loop's body — i.e. kernel launches per solver iteration. Returns -1
    if the trace contains no while loop.
    """
    closed = jax.make_jaxpr(fn)(*args)
    body = while_body_jaxpr(closed.jaxpr)
    if body is None:
        return -1
    return count_primitive(body, primitive)
