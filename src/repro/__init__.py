"""repro — Pipelined Conjugate Gradient on multi-pod TPU (JAX + Pallas).

Reproduction + beyond-paper optimization of Tiwari & Vadhiyar,
"Efficient executions of Pipelined Conjugate Gradient Method on
Heterogeneous Architectures" (2021), re-targeted from CPU+GPU nodes to
TPU pod meshes. See DESIGN.md for the mapping.

Entry point: ``repro.solve(A, b, method=..., engine=...)`` — one registry
over every solver method and kernel backend (see ``repro.api``).
"""

__version__ = "0.2.0"

_API = ("solve", "register_solver", "solver_names")


def __getattr__(name):
    # Lazy so `import repro` stays free of jax import cost/side effects.
    if name in _API:
        from . import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_API))
