"""repro — Pipelined Conjugate Gradient on multi-pod TPU (JAX + Pallas).

Reproduction + beyond-paper optimization of Tiwari & Vadhiyar,
"Efficient executions of Pipelined Conjugate Gradient Method on
Heterogeneous Architectures" (2021), re-targeted from CPU+GPU nodes to
TPU pod meshes. See DESIGN.md for the mapping.

Entry points: ``repro.plan(A, ...)`` -> reusable ``SolverPlan`` (setup
paid once, many right-hand sides), and the one-shot ``repro.solve(A, b,
method=..., engine=...)`` over a keyed plan cache (see ``repro.plan`` /
``repro.api``).
"""

__version__ = "0.3.0"

_API = (
    "solve",
    "plan",
    "SolverPlan",
    "register_solver",
    "solver_names",
    "plan_cache_stats",
    "clear_plan_cache",
)


def __getattr__(name):
    # Lazy so `import repro` stays free of jax import cost/side effects.
    if name == "obs":
        # the telemetry subsystem (spans/metrics/reports); jax-free import
        import importlib

        return importlib.import_module(".obs", __name__)
    if name == "plan":
        # the submodule doubles as the entry point: it is callable
        # (plan.__call__ == the plan() factory) and carries SolverPlan etc.
        # importlib, not `from . import`: the latter re-enters __getattr__.
        import importlib

        return importlib.import_module(".plan", __name__)
    if name in _API:
        from . import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API) | {"obs"})
