"""repro — Pipelined Conjugate Gradient on multi-pod TPU (JAX + Pallas).

Reproduction + beyond-paper optimization of Tiwari & Vadhiyar,
"Efficient executions of Pipelined Conjugate Gradient Method on
Heterogeneous Architectures" (2021), re-targeted from CPU+GPU nodes to
TPU pod meshes. See DESIGN.md for the mapping.
"""

__version__ = "0.1.0"
