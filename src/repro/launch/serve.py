"""Serving launcher: ``python -m repro.launch.serve --matrix poisson27:8``

The real entrypoint for the async serving tier (docs/serving.md): applies
the env hygiene from ``launch.env`` BEFORE the first jax import (XLA
flags, x64 policy, allocator thresholds; prints the tcmalloc preload line
when applicable), then stands up a :class:`repro.serve.SolverServer`,
pushes a mixed-size workload through it, and reports queue/bucket/
program telemetry.

    # cold start, mixed traffic, assert the two-program steady state
    python -m repro.launch.serve --matrix poisson27:8 --matrix poisson7:12 \
        --requests 48 --max-batch 4 --expect-two-programs

    # save a warm-start manifest, then boot a hot replica from it
    python -m repro.launch.serve --matrix poisson27:8 --save-manifest plans.json
    python -m repro.launch.serve --manifest plans.json --requests 32
"""
from __future__ import annotations

import argparse
import sys

# env hygiene must precede any jax import — keep this module jax-free
# until main() has called apply_env()
from .env import apply_env, tcmalloc_note


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", action="append", default=None,
                    help="operator spec (repeatable for a multi-plan pool); "
                         "see launch/solve.py (default: poisson27:8)")
    ap.add_argument("--requests", type=int, default=32,
                    help="requests pushed per operator")
    ap.add_argument("--method", default="pipecg")
    ap.add_argument("--engine", default="auto")
    ap.add_argument("--atol", type=float, default=1e-5)
    ap.add_argument("--maxiter", type=int, default=2000)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--max-depth", type=int, default=256)
    ap.add_argument("--devices", type=int, default=None,
                    help="virtual host devices (XLA flag; set before jax import)")
    ap.add_argument("--x64", action="store_true", help="enable fp64")
    ap.add_argument("--manifest", default=None,
                    help="warm-start: rebuild + re-trace plans from this manifest")
    ap.add_argument("--save-manifest", default=None,
                    help="write the served plans' manifest here on exit")
    ap.add_argument("--expect-two-programs", action="store_true",
                    help="exit nonzero unless steady state compiled exactly two "
                         "XLA programs (single + bucket) per plan")
    args = ap.parse_args(argv)

    # ---- env BEFORE jax (the whole reason this launcher exists) ----
    applied = apply_env(devices=args.devices, x64=True if args.x64 else None)
    for k, v in applied.items():
        print(f"env: {k}={v}")
    note = tcmalloc_note()
    if note:
        print(f"env note: {note}")

    import jax.numpy as jnp

    import repro.obs as obs
    from repro.serve import SolverServer
    from .solve import build_matrix

    obs.enable()

    if args.manifest:
        server = SolverServer.from_manifest(args.manifest)
        # route traffic with each plan's own config — CLI solver defaults
        # must not shadow the manifest, or submits would miss the warm
        # pool and trigger fresh builds
        workload = [(p.A, p.config()) for p in server.plans()]
        warm_traces = {id(p): p.trace_count for p in server.plans()}
        print(f"warm-started {len(server.plans())} plan(s) from {args.manifest}")
    else:
        server = SolverServer(
            max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
            max_depth=args.max_depth, method=args.method, engine=args.engine,
            atol=args.atol, maxiter=args.maxiter,
        )
        workload = [(build_matrix(s), {})
                    for s in (args.matrix or ["poisson27:8"])]
        warm_traces = None

    # ---- mixed-size workload: singles + partial + full buckets ----
    futures = []
    for A, overrides in workload:
        from repro.sparse import spmv

        xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
        b = spmv(A, xstar)
        # prime: one lone request, waited on, so the single-rhs program
        # traces deterministically (later singles may coalesce into buckets)
        futures.append(server.submit(A, b, **overrides))
        futures[-1].result(timeout=300.0)
        group, i = [], 1
        while i < args.requests:
            # cycle bucket sizes 1, cap, cap//2, 3 — singles exercise the
            # pinned single program, the rest coalesce into the bucket one
            for size in (1, args.max_batch, max(args.max_batch // 2, 1), 3):
                k = min(size, args.requests - i)
                if k <= 0:
                    break
                group += server.submit_many(
                    A, [(1.0 + 0.1 * (i + j)) * b for j in range(k)],
                    **overrides,
                )
                i += k
        futures += group
    results = [f.result(timeout=300.0) for f in futures]
    server.shutdown(drain=True)

    # ---- report ----
    waits = sorted(r.queue_wait_s for r in results)
    occ = [r.bucket_occupancy for r in results]
    iters = [r.iterations for r in results]
    p = lambda xs, q: xs[min(int(q * (len(xs) - 1)), len(xs) - 1)] if xs else 0.0
    print(f"served {len(results)} requests over {len(server.plans())} plan(s)")
    print(f"queue wait: p50={p(waits, .5) * 1e3:.2f}ms p95={p(waits, .95) * 1e3:.2f}ms")
    print(f"occupancy: mean={sum(occ) / max(len(occ), 1):.2f}  "
          f"iters: min={min(iters)} max={max(iters)}")
    for plan in server.plans():
        extra = ""
        if warm_traces is not None:
            boot = warm_traces.get(id(plan), 0)
            extra = f" (warm-start: {boot} at boot, " \
                    f"{plan.trace_count - boot} added serving)"
        print(f"plan n={plan.n}: compiled programs (trace_count)="
              f"{plan.trace_count}{extra}")
    rejects = {k: v["value"] for k, v in obs.snapshot().items()
               if k.startswith("serve.rejects.") and v["value"]}
    if rejects:
        print(f"rejections: {rejects}")

    if args.save_manifest:
        server.save_manifest(args.save_manifest)
        print(f"manifest saved: {args.save_manifest}")

    if args.expect_two_programs:
        bad = {p.n: p.trace_count for p in server.plans() if p.trace_count != 2}
        if bad:
            print(f"FAIL: expected exactly 2 compiled programs per plan "
                  f"(single + bucket), got {bad}", file=sys.stderr)
            return 1
        print("steady state OK: exactly 2 compiled programs per plan")
    if warm_traces is not None:
        added = {p.n: p.trace_count - warm_traces.get(id(p), 0)
                 for p in server.plans()
                 if p.trace_count != warm_traces.get(id(p), 0)}
        if added:
            print(f"FAIL: warm-started plans re-traced during serving: {added}",
                  file=sys.stderr)
            return 1
        print("warm start OK: zero new traces while serving")
    return 0


if __name__ == "__main__":
    sys.exit(main())
