"""Analytic FLOP / byte models per architecture family.

Used for the roofline's MODEL_FLOPS row and to cross-check the HLO
numbers (XLA's cost_analysis counts scan bodies once — see roofline.py for
the correction; the analytic model is the trip-count-exact reference).

All counts are GLOBAL (whole step across all chips); matmul flops = 2mnk.
Train multiplies matmul flops by 3 (fwd + 2x bwd).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.base import ArchConfig, ShapeConfig
from ..models.moe import moe_capacity

__all__ = ["model_flops_simple", "analytic_flops", "analytic_hbm_bytes", "param_count", "active_param_count"]


def param_count(cfg: ArchConfig) -> int:
    """Exact parameter count from the layout tree."""
    import numpy as np
    from ..models.zoo import build_model

    api = build_model(cfg)
    return api.n_params()


def active_param_count(cfg: ArchConfig) -> int:
    """Params touched per token (MoE: top_k of n_experts expert params)."""
    n = param_count(cfg)
    if cfg.n_experts and cfg.top_k:
        expert_params = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
        active = cfg.n_layers * cfg.top_k * 3 * cfg.d_model * cfg.d_ff
        return n - expert_params + active
    return n


def model_flops_simple(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """The required MODEL_FLOPS: 6*N*D (train) / 2*N*D (inference),
    N = active params, D = tokens processed this step."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


# ---------------------------------------------------------------------------
# detailed per-family counting (adds the non-weight attention/GLA terms that
# 6*N*D misses — quadratic attention dominates prefill_32k for dense archs)
# ---------------------------------------------------------------------------

def _attn_layer_flops(cfg, n_tok, kv_len) -> float:
    hd = cfg.head_dim_
    d = cfg.d_model
    proj = 2 * n_tok * d * (cfg.n_heads * hd) * 2  # wq + wo
    proj += 2 * n_tok * d * (cfg.n_kv_heads * hd) * 2  # wk + wv
    sdpa = 2 * n_tok * kv_len * cfg.n_heads * hd * 2  # QK^T + AV
    return proj + sdpa


def _mlp_flops(cfg, n_tok, f=None) -> float:
    f = cfg.d_ff if f is None else f
    return 3 * 2 * n_tok * cfg.d_model * f


def _moe_flops(cfg, n_tok) -> float:
    router = 2 * n_tok * cfg.d_model * cfg.n_experts
    comp = cfg.n_experts * moe_capacity(int(n_tok), cfg.top_k, cfg.n_experts, cfg.moe_capacity_factor)
    return router + 3 * 2 * comp * cfg.d_model * cfg.d_ff


def _gla_flops(cfg, n_tok, dk, dv, nh, chunk) -> float:
    intra = 2 * n_tok * chunk * nh * (dk + dv)
    inter = 2 * n_tok * nh * dk * dv * 2  # q@S + state update
    return intra + inter


def _mlstm_flops(cfg, n_tok, step=False) -> float:
    d, din = cfg.d_model, cfg.d_inner
    nh = cfg.ssm_heads_
    dk = din // nh
    proj = 2 * n_tok * d * 2 * din + 3 * 2 * n_tok * din * din + 2 * n_tok * din * d
    chunk = 1 if step else cfg.chunk
    return proj + _gla_flops(cfg, n_tok, dk, dk, nh, chunk)


def _slstm_flops(cfg, n_tok) -> float:
    d = cfg.d_model
    nh = cfg.ssm_heads_
    dh = d // nh
    return 2 * n_tok * d * 4 * d + 2 * n_tok * nh * dh * 4 * dh + 2 * n_tok * d * d


def _mamba_flops(cfg, n_tok, step=False) -> float:
    d, din = cfg.d_model, cfg.d_inner
    nh = cfg.ssm_heads_
    st = cfg.ssm_state
    dh = din // nh
    in_p = 2 * n_tok * d * (2 * din + 2 * st + nh)
    conv = 2 * n_tok * (din + 2 * st) * 4
    out_p = 2 * n_tok * din * d
    chunk = 1 if step else cfg.chunk
    return in_p + conv + out_p + _gla_flops(cfg, n_tok, st, dh, nh, chunk)


def analytic_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Detailed forward flops x (3 if train). Decode counts one step."""
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        n_tok, kv_len = B, T
    else:
        n_tok, kv_len = B * T, T

    fam = cfg.family
    total = 0.0
    if fam == "dense":
        total = cfg.n_layers * (_attn_layer_flops(cfg, n_tok, kv_len) + _mlp_flops(cfg, n_tok))
    elif fam == "moe":
        total = cfg.n_layers * (_attn_layer_flops(cfg, n_tok, kv_len) + _moe_flops(cfg, n_tok))
    elif fam == "ssm":
        n_s = cfg.n_layers // cfg.slstm_every
        n_m = cfg.n_layers - n_s
        total = n_m * _mlstm_flops(cfg, n_tok, step=shape.kind == "decode") + n_s * _slstm_flops(cfg, n_tok)
    elif fam == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        total = cfg.n_layers * _mamba_flops(cfg, n_tok, step=shape.kind == "decode")
        total += n_groups * (_attn_layer_flops(cfg, n_tok, kv_len) + _mlp_flops(cfg, n_tok))
    elif fam == "encdec":
        enc_tok = B * cfg.enc_seq
        enc = cfg.n_enc_layers * (_attn_layer_flops(cfg, enc_tok, cfg.enc_seq) + _mlp_flops(cfg, enc_tok))
        dec = cfg.n_layers * (
            _attn_layer_flops(cfg, n_tok, kv_len)
            + _attn_layer_flops(cfg, n_tok, cfg.enc_seq)  # cross
            + _mlp_flops(cfg, n_tok)
        )
        # decode recomputes no encoder; prefill/train include it
        total = dec + (enc if shape.kind != "decode" else 0.0)
    elif fam == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        n_self = n_groups * (cfg.cross_attn_every - 1)
        total = n_self * (_attn_layer_flops(cfg, n_tok, kv_len) + _mlp_flops(cfg, n_tok))
        total += n_groups * (
            _attn_layer_flops(cfg, n_tok, cfg.n_img_tokens) + _mlp_flops(cfg, n_tok)
        )
    else:
        raise ValueError(fam)

    total += 2.0 * n_tok * cfg.d_model * cfg.vocab_size  # unembed
    if shape.kind == "train":
        total *= 3.0
    return total


def analytic_hbm_bytes(cfg: ArchConfig, shape: ShapeConfig, dtype_bytes: int = 2) -> float:
    """First-order HBM traffic per step (global): weights + optimizer state
    (train) or weights + KV/state cache (decode) + major activations."""
    n = param_count(cfg)
    B, T = shape.global_batch, shape.seq_len
    d = cfg.d_model
    act_unit = B * T * d * dtype_bytes

    if shape.kind == "train":
        weights = n * dtype_bytes * 3          # read fwd + read bwd + write grad
        opt = n * 4 * 4                        # m,v read+write f32
        acts = cfg.n_layers * 8 * act_unit     # rough per-layer activation traffic
        logits = B * T * cfg.vocab_size * dtype_bytes * 2
        return weights + opt + acts + logits
    if shape.kind == "prefill":
        return n * dtype_bytes + cfg.n_layers * 6 * act_unit + B * T * cfg.vocab_size * dtype_bytes
    # decode: every weight + the whole KV cache (or SSM state) is read once
    hd = cfg.head_dim_
    if cfg.family == "ssm":
        din = cfg.d_inner
        nh = cfg.ssm_heads_
        cache = cfg.n_layers * B * nh * (din // nh) ** 2 * 4 * 2
    elif cfg.family == "hybrid":
        nh = cfg.ssm_heads_
        dh = cfg.d_inner // nh
        cache = cfg.n_layers * B * nh * cfg.ssm_state * dh * 4 * 2
        cache += (cfg.n_layers // cfg.attn_every) * B * T * cfg.n_kv_heads * hd * 2 * dtype_bytes
    else:
        L_kv = cfg.n_layers
        cache = L_kv * B * T * cfg.n_kv_heads * hd * 2 * dtype_bytes
    return n * dtype_bytes + cache + B * cfg.vocab_size * dtype_bytes
