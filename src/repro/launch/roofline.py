"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HBM bytes / (chips * 819e9 B/s)
    collective = wire bytes / (chips * 50e9 B/s per ICI link)

Methodology (and why ``compiled.cost_analysis()`` alone is not enough):
XLA's cost analysis counts ``lax.scan``/while bodies ONCE (verified: an
L-layer scanned stack reports exactly 1/L of the unrolled flops). Every
model here scans its layers, so we analyze the SPMD HLO text directly:

* the module is split into computations; while-loop trip counts are read
  from the literal bound in each loop condition; every computation gets a
  multiplier = product of enclosing trip counts;
* FLOPs: every ``dot`` instruction contributes 2 * |result| * contraction
  (operand shapes resolved within its computation) * multiplier. Elementwise
  flops are ignored — matmuls dominate all ten architectures;
* HBM bytes: every top-level instruction contributes |result| + sum
  |operands| (fusion internals excluded — post-fusion boundaries are what
  actually touches HBM) * multiplier. This is an ideal-fusion traffic
  model: the TPU figure assuming VMEM-resident fusion intermediates;
* collectives: wire bytes per chip with ring factors per kind; the HLO is
  the per-device SPMD module so shapes are already per-chip. NOTE: on this
  CPU backend XLA promotes bf16 all-reduces to f32 (``*_promoted`` reducers)
  — real-TPU wire bytes for those are half; reported as-is and called out
  in EXPERIMENTS.md.

``cost_analysis()`` raw numbers are also recorded for reference, and
launch/analytic.py provides the closed-form cross-check.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "HloAnalysis", "analyze_hlo", "roofline_terms"]

HW = {
    "peak_flops": 197e12,  # bf16 FLOP/s per chip
    "hbm_bw": 819e9,       # B/s per chip
    "ici_bw": 50e9,        # B/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(
    r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]"
)
_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w.\-_]+)\s+\(.*\)\s*->")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-_]+)\s*=\s*(.+)$")
_ATTR_RE = re.compile(r"(condition|body)=%?([\w.\-_]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-_]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = (
    "parameter(", "constant(", "tuple(", "get-tuple-element(", "bitcast(",
    "after-all(", "iota(",
)


def _shapes_of(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _shapes_of(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class HloAnalysis:
    flops: float = 0.0                 # per-chip, trip-adjusted (dots only)
    hbm_bytes: float = 0.0             # per-chip, trip-adjusted, ideal fusion
    wire_bytes: float = 0.0            # per-chip collective wire traffic
    coll_by_kind_bytes: Dict[str, float] = field(default_factory=dict)
    coll_by_kind_count: Dict[str, int] = field(default_factory=dict)
    n_whiles: int = 0
    notes: List[str] = field(default_factory=list)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)  # HBM bytes per op kind

    def _tally(self, body: str, amount: float):
        op = body.split("(", 1)[0].split()[-1] if "(" in body else body[:16]
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + amount
        self.hbm_bytes += amount

    def add_coll(self, kind: str, result_bytes: int, group: int, mult: float):
        if kind == "all-reduce":
            wire = 2.0 * result_bytes * (group - 1) / max(group, 1)
        elif kind == "all-gather":
            wire = result_bytes * (group - 1) / max(group, 1)
        elif kind == "reduce-scatter":
            wire = float(result_bytes) * (group - 1)
        elif kind == "all-to-all":
            wire = result_bytes * (group - 1) / max(group, 1)
        else:  # collective-permute
            wire = float(result_bytes)
        self.coll_by_kind_bytes[kind] = self.coll_by_kind_bytes.get(kind, 0.0) + wire * mult
        self.coll_by_kind_count[kind] = self.coll_by_kind_count.get(kind, 0) + 1
        self.wire_bytes += wire * mult


def _split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry = None
    for line in hlo.splitlines():
        if cur is None:
            if "->" in line and "{" in line:
                m = _COMP_START.match(line.strip())
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines: List[str]) -> int:
    best = 1
    for ln in cond_lines:
        for c in _CONST_RE.findall(ln):
            best = max(best, int(c))
    return best


def analyze_hlo(hlo: str) -> HloAnalysis:
    comps, entry = _split_computations(hlo)
    out = HloAnalysis()
    if entry is None:
        out.notes.append("no ENTRY computation found")
        return out

    # walk entry + while bodies only; fusion sub-computations are *not*
    # walked for flops/bytes (their boundaries are counted at call sites)
    work: List[Tuple[str, float]] = [(entry, 1.0)]
    seen: Dict[str, float] = {}
    while work:
        name, mult = work.pop()
        if name not in comps or seen.get(name, -1.0) >= mult:
            continue
        seen[name] = mult
        shape_map: Dict[str, int] = {}
        dims_map: Dict[str, List[int]] = {}
        for ln in comps[name]:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            lhs_name, rhs = m.group(1), m.group(2)
            # split "<type> <op>(...)" — the type may itself be a
            # parenthesized tuple "(f32[..], bf16[..])"
            if rhs.startswith("("):
                depth = 0
                end = 0
                for i, ch in enumerate(rhs):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                type_str = rhs[: end + 1]
                body = rhs[end + 2 :]
            else:
                type_end = rhs.find(" ")
                type_str = rhs[:type_end] if type_end > 0 else rhs
                body = rhs[type_end + 1 :] if type_end > 0 else ""
            shape_map[lhs_name] = _bytes_of(type_str)
            sh = _shapes_of(type_str)
            if len(sh) == 1:
                dims_map[lhs_name] = sh[0][1]
            if any(body.startswith(f) or f" {f}" in body.split(",")[0] for f in _FREE_OPS):
                continue

            if " while(" in body or body.startswith("while("):
                out.n_whiles += 1
                attrs = dict(_ATTR_RE.findall(body))
                trips = _trip_count(comps.get(attrs.get("condition", ""), []))
                for sub in ("body", "condition"):
                    if attrs.get(sub):
                        work.append((attrs[sub], mult * trips))
                continue

            # collectives
            matched_coll = False
            for kind in _COLL_KINDS:
                if f"{kind}(" in body and f"{kind}-done" not in body:
                    rb = _bytes_of(type_str)
                    gm = _GROUPS_RE.search(body)
                    if gm:
                        group = int(gm.group(2))
                    else:
                        gm2 = _GROUPS_OLD_RE.search(body)
                        group = len(gm2.group(1).split(",")) if gm2 else 2
                    out.add_coll(kind, rb, group, mult)
                    matched_coll = True
                    break

            # operand list = first (...) group after the op name
            p0 = body.find("(")
            p1 = body.find(")", p0)
            operands = _OPERAND_RE.findall(body[p0 + 1 : p1]) if p0 >= 0 and p1 > p0 else []
            res_bytes = _bytes_of(type_str)

            if not matched_coll:
                # in-place / windowed ops: charge the moved window, not the
                # aliased full buffer (XLA updates loop-carried stacks in place)
                if "dynamic-update-slice(" in body:
                    upd = shape_map.get(operands[1], 0) if len(operands) > 1 else 0
                    out._tally(body, 2.0 * upd * mult)
                elif "dynamic-slice(" in body or " gather(" in body or body.startswith("gather("):
                    out._tally(body, 2.0 * res_bytes * mult)
                elif ("dynamic-update-slice" in lhs_name or "dynamic_update_slice" in lhs_name
                      or " scatter(" in body or body.startswith("scatter(")):
                    # fused DUS/scatter: charge operands smaller than the result
                    small = sum(
                        b for b in (shape_map.get(o, 0) for o in operands) if b < res_bytes
                    )
                    out._tally(body, 2.0 * small * mult)
                else:
                    op_bytes = sum(shape_map.get(o, 0) for o in operands)
                    # fusions that internally dynamic-slice a loop-invariant
                    # stack (scan-sliced weights/caches) only read the slice,
                    # not the whole operand they reference
                    cm = re.search(r"calls=%?([\w.\-_]+)", body)
                    if cm and op_bytes > 4 * res_bytes:
                        callee = comps.get(cm.group(1), [])
                        if any("dynamic-slice(" in c for c in callee):
                            op_bytes = min(op_bytes, 2 * res_bytes)
                    out._tally(body, (res_bytes + op_bytes) * mult)

            # dot flops
            if " dot(" in body or body.startswith("dot("):
                res_elems = 1
                for _, dims in _shapes_of(type_str):
                    e = 1
                    for d in dims:
                        e *= d
                    res_elems *= max(e, 1)
                cm = _CONTRACT_RE.search(body)
                contract = 1
                if cm and operands:
                    lhs_dims = dims_map.get(operands[0], [])
                    for idx in (int(i) for i in cm.group(1).split(",") if i):
                        if idx < len(lhs_dims):
                            contract *= lhs_dims[idx]
                out.flops += 2.0 * res_elems * contract * mult

    return out


def roofline_terms(flops_per_chip: float, hbm_bytes_per_chip: float, wire_bytes_per_chip: float) -> Dict[str, float]:
    compute = flops_per_chip / HW["peak_flops"]
    memory = hbm_bytes_per_chip / HW["hbm_bw"]
    collective = wire_bytes_per_chip / HW["ici_bw"]
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    terms["bound_s"] = max(compute, memory, collective)
    return terms
