"""Launch layer: env hygiene, entrypoints, production meshes, rooflines.

Lazy exports: importing ``repro.launch`` (or ``repro.launch.env``) must
NOT import jax — the whole point of ``launch.env.apply_env`` is to run
before the first jax import, and an eager ``from .mesh import ...`` here
would defeat it.
"""
_LAZY = {
    "make_production_mesh": ".mesh",
    "make_solver_mesh_from": ".mesh",
    "apply_env": ".env",
    "tcmalloc_note": ".env",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(_LAZY[name], __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
