"""Launch layer: production meshes, sharding rules, dry-run, rooflines."""
from .mesh import make_production_mesh, make_solver_mesh_from

__all__ = ["make_production_mesh", "make_solver_mesh_from"]
