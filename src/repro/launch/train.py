"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

On this CPU box it runs reduced configs end-to-end (the real-training
example path); on a TPU fleet the same launcher takes ``--full`` and the
production mesh. Wires together: config -> model -> sharding rules ->
train_step -> synthetic data -> CheckpointManager (async, crash-safe) ->
supervised recovery loop.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config, list_configs, reduced
from ..data import SyntheticConfig, batch_for_step
from ..models import build_model
from ..models.common import use_sharding_rules
from ..runtime import CheckpointManager, run_with_recovery
from ..train import AdamWConfig, TrainConfig, init_train_state, make_train_step, warmup_cosine
from .mesh import make_production_mesh
from .sharding import DEFAULT_RULES, make_resolver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_configs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--pipelined-clip", action="store_true")
    ap.add_argument("--fused-optimizer", action="store_true")
    ap.add_argument("--full", action="store_true", help="full config + production mesh (TPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else reduced(get_config(args.arch))
    api = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} params={api.n_params():,} full={args.full}")

    tc = TrainConfig(
        optimizer=AdamWConfig(
            lr=args.lr, clip_norm=1.0,
            pipelined_clip=args.pipelined_clip,
            apply_fused=args.fused_optimizer,
        ),
        remat=args.remat,
        microbatches=args.microbatches,
    )
    step_raw = make_train_step(api, tc, lr_schedule=warmup_cosine(args.lr, 20, args.steps))

    ctx = None
    if args.full:
        mesh = make_production_mesh()
        rules = DEFAULT_RULES()
        ctx = use_sharding_rules(make_resolver(mesh, rules))
        ctx.__enter__()
    step_jit = jax.jit(step_raw, donate_argnums=(0,))

    state = init_train_state(api, jax.random.PRNGKey(0))
    dc = SyntheticConfig(batch=args.batch, seq_len=args.seq, vocab_size=cfg.vocab_size, seed=0)
    mgr = CheckpointManager(args.ckpt_dir, save_every=args.save_every, keep=3)
    restored, s0 = mgr.restore_latest(jax.eval_shape(lambda: state))
    start = 0
    if restored is not None:
        state, start = restored, s0
        print(f"resumed from step {start}")

    t0 = time.time()
    metrics_box = {}

    def one_step(state, step):
        batch = {k: jnp.asarray(v) for k, v in batch_for_step(dc, step, cfg).items()}
        state, metrics = step_jit(state, batch)
        if step % 10 == 0:
            print(
                f"step {step:4d} loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} ({time.time()-t0:.1f}s)"
            )
        metrics_box.update({k: float(v) for k, v in metrics.items()})
        return state

    state, end = run_with_recovery(one_step, state, args.steps, mgr, start_step=start)
    print(f"finished at step {end}: loss={metrics_box.get('loss'):.4f} in {time.time()-t0:.1f}s")
    if ctx is not None:
        ctx.__exit__(None, None, None)


if __name__ == "__main__":
    main()
