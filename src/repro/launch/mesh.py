"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the leading
"pod" axis is the data-parallel axis that crosses the inter-pod links
(DCN/ICI-over-optical), which is why gradient reductions are laid out
pod-major (cheapest collective crosses the slowest fabric exactly once).
"""
from __future__ import annotations

import jax

from ..compat import AxisType, make_mesh

__all__ = ["make_production_mesh", "make_solver_mesh_from", "DATA_AXES", "MODEL_AXIS"]

DATA_AXES = ("pod", "data")  # batch shards over whichever of these exist
MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np

    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devs)} are visible — "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import"
        )
    return make_mesh(shape, axes, devices=devs[:n], axis_types=(AxisType.Auto,) * len(axes))


def make_solver_mesh_from(mesh) -> "jax.sharding.Mesh":
    """1-D 'rows' view over the same devices for the shard_map solver."""
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(mesh.devices).reshape(-1), ("rows",))
