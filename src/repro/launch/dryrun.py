import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e + g).

For every (architecture x input shape) cell this lowers AND compiles the
real step program — ``train_step`` for train shapes, ``prefill`` for
prefill shapes, ``serve_step`` (one-token decode against a seq_len cache)
for decode shapes — on the production meshes:

    single-pod : 16 x 16        ("data", "model")      = 256 chips
    multi-pod  : 2 x 16 x 16    ("pod", "data", "model") = 512 chips

and extracts the roofline inputs: cost_analysis, memory_analysis, and the
collective census of the SPMD HLO. Scan-body undercounting is corrected by
a 2-point layer-count fit (see launch/roofline.py). Results land as JSON
under --out for EXPERIMENTS.md.

The XLA_FLAGS line above MUST run before any jax import — jax locks the
device count at first init. Everything else imports lazily below it.
"""
import argparse
import dataclasses
import json
import time
import traceback
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_config, list_configs
from ..configs.base import ArchConfig, ShapeConfig
from ..models import build_model
from ..models.common import use_sharding_rules
from ..train import AdamWConfig, TrainConfig, abstract_train_state, make_train_step
from ..train.train_step import TrainState
from ..train.optimizer import AdamWState
from .analytic import analytic_flops, analytic_hbm_bytes, model_flops_simple, param_count
from .mesh import make_production_mesh
from .roofline import HW, analyze_hlo, roofline_terms
from .sharding import (
    DEFAULT_RULES,
    batch_shardings,
    cache_shardings,
    make_resolver,
    param_shardings,
    scalar_sharding,
)

__all__ = ["run_cell", "main"]


def _group_size(cfg: ArchConfig) -> int:
    if cfg.family == "ssm":
        return cfg.slstm_every
    if cfg.family == "hybrid":
        return cfg.attn_every
    if cfg.family == "vlm":
        return cfg.cross_attn_every
    return 1


def _with_groups(cfg: ArchConfig, groups: int) -> ArchConfig:
    g = _group_size(cfg)
    new = {"n_layers": groups * g}
    if cfg.family == "encdec":
        new["n_enc_layers"] = groups
    return replace(cfg, **new)


def _lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, rules, variant: dict | None = None):
    """Lower the appropriate step program; returns (lowered, meta).

    variant (perf-iteration knobs, EXPERIMENTS.md §Perf):
      remat: True | "save_collectives"
      cache_layout: "default" | "seq_model"
      pipelined_clip: bool
    """
    variant = variant or {}
    api = build_model(cfg)
    resolver = make_resolver(mesh, rules)
    p_sh = param_shardings(api, mesh, rules)
    specs = api.input_specs(shape)

    if shape.kind == "train":
        tc = TrainConfig(
            optimizer=AdamWConfig(
                lr=1e-4, clip_norm=1.0, pipelined_clip=variant.get("pipelined_clip", False)
            ),
            remat=variant.get("remat", True),
        )
        step = make_train_step(api, tc)
        state_sds = abstract_train_state(api)
        sc = scalar_sharding(mesh)
        state_sh = TrainState(
            params=p_sh,
            opt=AdamWState(m=p_sh, v=p_sh, step=sc, prev_norm=sc),
            step=sc,
        )
        b_sh = batch_shardings(specs, mesh, rules)
        with mesh, use_sharding_rules(resolver, mesh if variant.get("moe_shard_map") else None):
            lowered = jax.jit(
                step, in_shardings=(state_sh, b_sh), donate_argnums=(0,)
            ).lower(state_sds, specs)
        return lowered, {"program": "train_step"}

    if shape.kind == "prefill":
        params_sds = api.abstract_params()
        b_sh = batch_shardings(specs, mesh, rules)
        with mesh, use_sharding_rules(resolver, mesh if variant.get("moe_shard_map") else None):
            lowered = jax.jit(api.prefill, in_shardings=(p_sh, b_sh)).lower(params_sds, specs)
        return lowered, {"program": "prefill"}

    # decode
    params_sds = api.abstract_params()
    cache_sds = specs["cache"]
    c_sh = cache_shardings(cache_sds, shape, mesh, rules, layout=variant.get("cache_layout", "default"))
    tok_sh = batch_shardings({"token": specs["token"]}, mesh, rules)["token"]

    def serve_step(params, token, cache, pos):
        return api.decode(params, token, cache, pos)

    with mesh, use_sharding_rules(resolver, mesh if variant.get("moe_shard_map") else None):
        lowered = jax.jit(
            serve_step,
            in_shardings=(p_sh, tok_sh, c_sh, scalar_sharding(mesh)),
            donate_argnums=(2,),
        ).lower(params_sds, specs["token"], cache_sds, jnp.int32(shape.seq_len - 1))
    return lowered, {"program": "serve_step"}


def _cost(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older JAX: one dict per device
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)), "bytes": float(ca.get("bytes accessed", 0.0))}


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, fit: bool = True, verbose: bool = True,
             variant: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    variant = variant or {}
    if variant.get("attn_chunk"):
        cfg = replace(cfg, attn_chunk=int(variant["attn_chunk"]))
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "variant": variant,
    }
    if shape_name == "long_500k" and not cfg.subquadratic:
        rec["status"] = "skipped"
        rec["reason"] = "full quadratic attention at 524288 — skipped by design (DESIGN.md §4)"
        return rec

    n_chips = 512 if multi_pod else 256
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = DEFAULT_RULES()
    t0 = time.time()
    lowered, meta = _lower_cell(cfg, shape, mesh, rules, variant)
    rec.update(meta)
    rec["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        "peak_bytes_per_device": int(getattr(ma, "peak_memory_in_bytes", 0)),
    }
    raw = _cost(compiled)
    rec["hlo_raw_cost_analysis"] = raw  # scan bodies counted once — reference only

    hl = analyze_hlo(compiled.as_text())
    rec["hlo"] = {
        "flops_per_chip": hl.flops,
        "hbm_bytes_per_chip": hl.hbm_bytes,
        "wire_bytes_per_chip": hl.wire_bytes,
        "n_whiles": hl.n_whiles,
    }
    rec["collectives"] = {
        "wire_bytes_per_chip": hl.wire_bytes,
        "by_kind_bytes": hl.coll_by_kind_bytes,
        "by_kind_count": hl.coll_by_kind_count,
    }
    rec["sharding_fallbacks"] = [
        {"shape": list(s), "axis": a, "why": w} for (s, a, w) in rules.dropped[:20]
    ]
    flops_pc = hl.flops
    bytes_pc = hl.hbm_bytes

    # --- analytic reference (global) ---
    rec["analytic"] = {
        "model_flops_6nd": model_flops_simple(cfg, shape),
        "detailed_flops": analytic_flops(cfg, shape),
        "hbm_bytes": analytic_hbm_bytes(cfg, shape),
        "params": param_count(cfg),
    }

    # --- roofline terms (per chip) ---
    terms = roofline_terms(flops_pc, bytes_pc, hl.wire_bytes)
    rec["roofline_hlo"] = terms
    an = rec["analytic"]
    terms_an = roofline_terms(
        an["detailed_flops"] / n_chips, an["hbm_bytes"] / n_chips, hl.wire_bytes
    )
    rec["roofline_analytic"] = terms_an
    rec["model_vs_hlo_flops"] = (
        an["model_flops_6nd"] / (flops_pc * n_chips) if flops_pc else None
    )
    if verbose:
        print(
            f"[{rec['mesh']}] {arch:24s} {shape_name:12s} {rec['program']:10s} "
            f"compile={rec['compile_s']:6.1f}s peak/dev={rec['memory']['peak_bytes_per_device']/2**30:7.2f}GiB "
            f"dom={terms_an['dominant']:10s} bound={terms_an['bound_s']*1e3:9.3f}ms",
            flush=True,
        )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-fit", action="store_true")
    args = ap.parse_args(argv)

    archs = list_configs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                try:
                    rec = run_cell(arch, shape, mp, fit=(not args.no_fit) and not mp)
                except Exception as e:  # a failure here is a bug in the system
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                    failures.append(tag)
                    print(f"FAILED {tag}: {e}", flush=True)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1, default=float)
    print(f"\ndone; {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
