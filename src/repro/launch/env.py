"""Process/env hygiene applied BEFORE importing jax.

The run.sh idiom from the exemplar repos (SNIPPETS.md), as a callable:
tcmalloc preload note, XLA flags, allocator-warning thresholds and the
x64 policy all must be in the environment before ``import jax`` — after
that, XLA has read its flags and the dtype default is frozen. This
module therefore imports NOTHING heavy (no jax, no numpy) and is safe to
import first in any entrypoint:

    from repro.launch.env import apply_env
    apply_env(devices=8)          # BEFORE any jax import
    import jax                    # sees 8 virtual CPU devices

``apply_env`` is import-order safe and idempotent: it is a silent no-op
for every variable already set (an operator's explicit environment always
wins — CI sets XLA_FLAGS itself), and a no-op with a warning when jax was
imported first (setting the vars then would silently do nothing, which is
worse than saying so). ``launch/serve.py`` and ``launch/solve.py`` call
it on startup.

LD_PRELOAD (tcmalloc) cannot take effect from inside a running process —
:func:`tcmalloc_note` returns the export line to put in a wrapper script
when a system tcmalloc exists and none is preloaded.
"""
from __future__ import annotations

import os
import sys
import warnings
from typing import Dict, Mapping, Optional, Sequence

__all__ = ["apply_env", "tcmalloc_note", "DEFAULT_ENV", "TCMALLOC_PATHS"]

# vars applied when (and only when) absent — the SNIPPETS run.sh set
DEFAULT_ENV: Dict[str, str] = {
    # silence tcmalloc's large-alloc warnings for matrix-sized buffers
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
    # keep TF/XLA C++ chatter out of serving logs
    "TF_CPP_MIN_LOG_LEVEL": "2",
}

TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def tcmalloc_note(env: Mapping[str, str] = os.environ) -> Optional[str]:
    """The LD_PRELOAD line a launcher script should add, or None.

    Returns the export line when a system tcmalloc exists and nothing is
    preloaded yet; preloading must happen before process start, so this
    is advisory — print it, don't set it.
    """
    if env.get("LD_PRELOAD"):
        return None
    for path in TCMALLOC_PATHS:
        if os.path.exists(path):
            return f"export LD_PRELOAD={path}  # faster malloc (set before launch)"
    return None


def apply_env(
    devices: Optional[int] = None,
    x64: Optional[bool] = None,
    extra_xla_flags: Sequence[str] = (),
    env: Optional[Dict[str, str]] = None,
) -> Dict[str, str]:
    """Set the pre-jax environment; returns {var: value} actually set.

    * ``devices`` — virtual host-platform device count
      (``--xla_force_host_platform_device_count``), the CPU idiom for
      exercising shard_map meshes.
    * ``x64`` — the precision policy: sets ``JAX_ENABLE_X64`` (the
      solvers are f32-first; residual replacement is the accuracy net).
    * ``extra_xla_flags`` — appended to ``XLA_FLAGS`` unless the same
      flag is already present.

    Every variable already present in ``env`` is left untouched (no-op),
    and a flag already in ``XLA_FLAGS`` is never duplicated or
    overridden. If jax is already imported (and ``env`` is the real
    ``os.environ``), nothing is set and a warning explains why.
    """
    real = env is None
    if env is None:
        env = os.environ  # type: ignore[assignment]
    if real and "jax" in sys.modules:
        warnings.warn(
            "repro.launch.env.apply_env() called after jax was imported: "
            "XLA flags and the x64 policy are already frozen, so nothing "
            "was changed. Call apply_env() before the first jax import.",
            stacklevel=2,
        )
        return {}

    applied: Dict[str, str] = {}
    for k, v in DEFAULT_ENV.items():
        if k not in env:
            env[k] = v
            applied[k] = v
    if x64 is not None and "JAX_ENABLE_X64" not in env:
        env["JAX_ENABLE_X64"] = "1" if x64 else "0"
        applied["JAX_ENABLE_X64"] = env["JAX_ENABLE_X64"]

    current = env.get("XLA_FLAGS", "")
    new_flags = []
    if devices is not None and "--xla_force_host_platform_device_count" not in current:
        new_flags.append(f"--xla_force_host_platform_device_count={int(devices)}")
    for flag in extra_xla_flags:
        if flag.split("=", 1)[0] not in current:
            new_flags.append(flag)
    if new_flags:
        env["XLA_FLAGS"] = " ".join(([current] if current else []) + new_flags)
        applied["XLA_FLAGS"] = env["XLA_FLAGS"]
    return applied
