"""Logical-axis sharding rules with divisibility-aware degradation.

Rules map logical axis names (from models/common.ParamSpec and the
shard_hint call sites) to mesh axes. JAX requires every explicitly sharded
input dim to divide the mesh axis product, so ``resolve_spec`` drops any
rule whose dim doesn't divide — the arch still compiles, just with that
tensor replicated along the dropped axis (recorded so the dry-run can
report degradations, e.g. qwen2.5's kv_flat=1024 on a 16-way model axis is
fine, but whisper's 6-head q projection of 384 falls back).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "DEFAULT_RULES", "resolve_spec", "make_resolver", "param_shardings",
           "batch_shardings", "cache_shardings", "scalar_sharding"]

MeshAxes = Union[str, Tuple[str, ...], None]


@dataclass
class Rules:
    table: Dict[str, MeshAxes]
    dropped: list = field(default_factory=list)  # (shape, axis, reason) log

    def get(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        return self.table.get(name)


def DEFAULT_RULES() -> Rules:
    return Rules(
        table={
            "batch": ("pod", "data"),
            "vocab": "model",
            "heads_flat": "model",
            "kv_flat": "model",
            "heads": "model",
            "mlp": "model",
            "experts": "model",
            "expert_mlp": None,
            "embed": None,
            "layers": None,
            "seq": None,
        }
    )


def _present_axes(mesh: Mesh, axes: MeshAxes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if a in mesh.shape)


def resolve_spec(shape: Sequence[int], logical: Sequence[Optional[str]], mesh: Mesh,
                 rules: Rules) -> P:
    """Build a PartitionSpec, dropping non-dividing / duplicate mesh axes."""
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        axes = _present_axes(mesh, rules.get(name))
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            parts.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size != 0:
            # try a prefix of the axes before giving up
            ok = ()
            for k in range(len(axes) - 1, 0, -1):
                size_k = int(np.prod([mesh.shape[a] for a in axes[:k]]))
                if dim % size_k == 0:
                    ok = axes[:k]
                    break
            if not ok:
                rules.dropped.append((tuple(shape), name, f"{dim} % {size} != 0"))
                parts.append(None)
                continue
            axes = ok
        used.update(axes)
        parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def make_resolver(mesh: Mesh, rules: Rules):
    """Resolver for models.common.use_sharding_rules (activation hints)."""

    def resolver(shape, logical):
        spec = resolve_spec(shape, logical, mesh, rules)
        return NamedSharding(mesh, spec)

    return resolver


def param_shardings(api, mesh: Mesh, rules: Rules):
    """NamedSharding tree matching api.abstract_params()."""
    axes_tree = api.param_logical_axes()
    abstract = api.abstract_params()
    return jax.tree.map(
        lambda sds, ax: NamedSharding(mesh, resolve_spec(sds.shape, ax, mesh, rules)),
        abstract,
        axes_tree,
    )


def batch_shardings(specs: dict, mesh: Mesh, rules: Rules):
    """Shard every batch input on its leading (batch) dim."""
    def one(sds):
        logical = ["batch"] + [None] * (len(sds.shape) - 1)
        return NamedSharding(mesh, resolve_spec(sds.shape, logical, mesh, rules))

    return {k: one(v) if hasattr(v, "shape") else v for k, v in specs.items()}


def cache_shardings(cache_tree, shape_cfg, mesh: Mesh, rules: Rules, layout: str = "default"):
    """Heuristic decode-cache layouts.

    layout="default":
      * any dim equal to global_batch shards over the data axes (if divisible);
      * else a dim equal to seq_len shards over 'data' (context parallelism —
        the long_500k batch=1 case);
      * the trailing (feature/head_dim) axis shards over 'model' if divisible.
    layout="seq_model" (flash-decode, §Perf): additionally shard the cache
      SEQUENCE axis over 'model'. Attention then computes per-shard partial
      softmax stats and psums tiny (B, H) reductions instead of resharding
      the multi-GiB cache every step.
    Scalars (pos) replicate.
    """
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    data_axes = _present_axes(mesh, ("pod", "data"))
    data_size = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    model_size = mesh.shape.get("model", 1)

    def one(sds):
        if not hasattr(sds, "shape") or len(sds.shape) == 0:
            return NamedSharding(mesh, P())
        parts = [None] * len(sds.shape)
        batch_done = False
        for i, d in enumerate(sds.shape):
            if d == B and not batch_done and B % data_size == 0 and B >= data_size:
                parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                batch_done = True
                break
        if not batch_done and "data" in mesh.shape:
            for i, d in enumerate(sds.shape):
                if d == S and S % mesh.shape["data"] == 0:
                    parts[i] = "data"
                    batch_done = True
                    break
        if layout == "seq_model":
            for i, d in enumerate(sds.shape):
                if parts[i] is None and d == S and S % model_size == 0:
                    parts[i] = "model"
                    return NamedSharding(mesh, P(*parts))
        last = len(sds.shape) - 1
        if parts[last] is None and sds.shape[last] % model_size == 0 and sds.shape[last] >= model_size:
            parts[last] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, cache_tree)


def scalar_sharding(mesh: Mesh):
    return NamedSharding(mesh, P())
