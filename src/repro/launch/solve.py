"""Solver launcher: ``python -m repro.launch.solve --matrix poisson125:16``

Single-device or distributed (--shards N, needs that many devices — on CPU
set XLA_FLAGS=--xla_force_host_platform_device_count=N before launch).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..core import chronopoulos_cg, jacobi, pcg, pipecg
from ..core.distributed import make_solver_mesh, pipecg_distributed
from ..core.perfmodel import decompose
from ..sparse import (
    balanced_rows,
    poisson7,
    poisson27,
    poisson125,
    shard_dia,
    shard_vector,
    spmv,
    synthetic_spd_dia,
    table1_matrix,
    unshard_vector,
)

GENS = {"poisson7": poisson7, "poisson27": poisson27, "poisson125": poisson125}


def build_matrix(spec: str):
    name, _, arg = spec.partition(":")
    if name in GENS:
        return GENS[name](int(arg or 8))
    if name == "synthetic":
        n, _, nnz = (arg or "1000,9").partition(",")
        return synthetic_spd_dia(int(n), float(nnz or 9))
    return table1_matrix(name, scale=float(arg or 1.0))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", default="poisson27:12")
    ap.add_argument("--solver", default="pipecg", choices=["pcg", "chronopoulos", "pipecg"])
    ap.add_argument("--engine", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--method", default="h3", choices=["h1", "h2", "h3"])
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--atol", type=float, default=1e-5)
    ap.add_argument("--maxiter", type=int, default=10000)
    ap.add_argument("--replace-every", type=int, default=0)
    ap.add_argument("--weighted", action="store_true", help="nnz perf-model partition (h3)")
    args = ap.parse_args(argv)

    A = build_matrix(args.matrix)
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
    b = spmv(A, xstar)
    M = jacobi(A)
    print(f"matrix {args.matrix}: N={A.n} nnz/N={A.nnz()/A.n:.1f} bw={A.bandwidth}")

    if args.shards > 1:
        if len(jax.devices()) < args.shards:
            raise SystemExit(
                f"need {args.shards} devices; set XLA_FLAGS=--xla_force_host_platform_device_count={args.shards}"
            )
        bounds = (
            decompose(A, args.shards) if args.weighted else balanced_rows(A.n, args.shards)
        )
        As = shard_dia(A, bounds)
        mesh = make_solver_mesh(args.shards)
        res = pipecg_distributed(
            As, shard_vector(b, bounds), shard_vector(M.inv_diag, bounds),
            mesh=mesh, method=args.method, atol=args.atol, maxiter=args.maxiter,
        )
        x = unshard_vector(res.x, bounds)
    else:
        solver = {"pcg": pcg, "chronopoulos": chronopoulos_cg, "pipecg": pipecg}[args.solver]
        kw = {}
        if args.solver == "pipecg":
            kw = {"engine": args.engine, "replace_every": args.replace_every}
        res = solver(A, b, M=M, atol=args.atol, maxiter=args.maxiter, **kw)
        x = res.x

    err = float(jnp.linalg.norm(x - xstar))
    true_res = float(jnp.linalg.norm(b - spmv(A, x)))
    print(
        f"iters={int(res.iterations)} converged={bool(res.converged)} "
        f"|u|={float(res.residual_norm):.2e} |x-x*|={err:.2e} true_res={true_res:.2e}"
    )


if __name__ == "__main__":
    main()
