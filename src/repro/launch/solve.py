"""Solver launcher: ``python -m repro.launch.solve --matrix poisson125:16``

Thin CLI over the plan/execute API: builds one ``repro.plan`` (setup paid
once, printed via ``plan.describe()``), then solves. Single-device or
distributed (--shards N, needs that many devices — on CPU set
XLA_FLAGS=--xla_force_host_platform_device_count=N before launch).
``--rhs K`` serves K right-hand sides through the same plan
(``plan.solve_batched``) to demonstrate the amortization.
"""
from __future__ import annotations

import argparse

import sys

# env hygiene BEFORE the first jax import (repro and repro.launch are
# both lazy, so running `python -m repro.launch.solve` reaches this line
# jax-free); a no-op for every variable the operator already set. Guarded
# so importing this module for build_matrix() from an already-running
# process stays silent.
if "jax" not in sys.modules:
    from .env import apply_env

    apply_env()

import jax.numpy as jnp

from .. import plan, solver_names
from ..sparse import poisson7, poisson27, poisson125, spmv, synthetic_spd_dia, table1_matrix

GENS = {"poisson7": poisson7, "poisson27": poisson27, "poisson125": poisson125}


def build_matrix(spec: str):
    name, _, arg = spec.partition(":")
    if name in GENS:
        return GENS[name](int(arg or 8))
    if name == "synthetic":
        n, _, nnz = (arg or "1000,9").partition(",")
        return synthetic_spd_dia(int(n), float(nnz or 9))
    return table1_matrix(name, scale=float(arg or 1.0))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", default="poisson27:12")
    ap.add_argument("--method", default=None, choices=sorted(set(solver_names())),
                    help="solver method; h1..h4/pl2/pl3 are distributed (set --shards; "
                         "h4 also needs --sub); default: pipecg, or h3 when --shards > 1")
    ap.add_argument("--solver", default=None, help="deprecated alias for --method")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "jnp", "pallas", "fused_iter"],
                    help="iteration core; fused_iter = whole-iteration kernel (pipecg, DIA)")
    ap.add_argument("--spmv-engine", default=None,
                    choices=["auto", "jnp", "pallas", "segsum", "bf16"],
                    help="SPMV backend (pipecg); bf16 = half-traffic mixed precision")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--atol", type=float, default=1e-5)
    ap.add_argument("--maxiter", type=int, default=10000)
    ap.add_argument("--replace-every", type=int, default=None,
                    help="residual-replacement period (default: 0, or 50 under bf16)")
    ap.add_argument("--weighted", action="store_true", help="nnz perf-model partition (h3)")
    ap.add_argument("--sub", type=int, default=None,
                    help="reducer sub-axis size: shards devices become a "
                         "(shards/sub, sub) pod mesh (required by h4)")
    ap.add_argument("--rhs", type=int, default=1,
                    help="number of right-hand sides served through the one plan")
    args = ap.parse_args(argv)

    A = build_matrix(args.matrix)
    xstar = jnp.ones((A.n,)) / jnp.sqrt(A.n)
    b = spmv(A, xstar)
    print(f"matrix {args.matrix}: N={A.n} nnz/N={A.nnz()/A.n:.1f} bw={A.bandwidth}")

    distributed = ("h1", "h2", "h3", "h4", "pl2", "pl3", "pipecg_distributed")
    method = args.solver or args.method
    kw = {}
    if args.shards > 1:
        if method is None:
            method = "h3"
        elif method not in distributed:
            ap.error(f"--method {method} is single-device; with --shards use one of {distributed}")
        kw = {"shards": args.shards, "partition": "nnz" if args.weighted else "rows"}
        if args.sub is not None:
            kw["sub"] = args.sub
        if args.replace_every is not None:
            kw["replace_every"] = args.replace_every
    else:
        if method is None:
            method = "pipecg"
        elif method in distributed:
            ap.error(f"--method {method} is distributed; set --shards > 1")
        if method == "pipecg":
            kw = {"replace_every": args.replace_every, "spmv_engine": args.spmv_engine}

    # --- the plan/execute split: setup once... ---
    p = plan(A, method=method, engine=args.engine, M="jacobi",
             atol=args.atol, maxiter=args.maxiter, **kw)
    desc = p.describe()
    print("plan:", ", ".join(f"{k}={desc[k]}" for k in sorted(desc) if k != "trace_count"))

    # --- ...then any amount of rhs traffic ---
    res = p.solve(b)
    if args.rhs > 1:
        B = jnp.stack([(k + 1.0) * b for k in range(args.rhs)])
        batch = p.solve_batched(B)
        print(
            f"served {args.rhs} rhs through one plan: "
            f"iters={[int(i) for i in jnp.atleast_1d(batch.iterations)]} "
            f"traces={p.trace_count}"
        )

    err = float(jnp.linalg.norm(res.x - xstar))
    true_res = float(jnp.linalg.norm(b - spmv(A, res.x)))
    print(
        f"method={method} iters={int(res.iterations)} converged={bool(res.converged)} "
        f"|u|={float(res.residual_norm):.2e} |x-x*|={err:.2e} true_res={true_res:.2e}"
    )


if __name__ == "__main__":
    main()
