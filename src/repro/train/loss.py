"""Cross-entropy loss with the paper-technique metric packing.

``packed_metrics`` returns ONE vector [sum_nll, token_count, grad_norm_sq,
aux] so the training loop issues a single reduction per step instead of
one per metric — the Hybrid-PIPECG-2 move (shrink many small syncs into
one) applied to training telemetry.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ce_loss", "next_token_loss"]


def ce_loss(logits: jax.Array, labels: jax.Array, *, z_loss: float = 0.0):
    """Mean CE over all positions. logits (B,T,V) any float; labels (B,T).

    Written as lse - label_logit with an iota/select reduction (NOT
    take_along_axis): under a vocab-sharded logits layout the select fuses
    into the vocab-axis reduction and GSPMD finishes with a tiny psum,
    whereas a gather on the sharded axis would all-gather the full logits.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)  # (B,T) — sharded vocab reduce
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, len(lf.shape) - 1)
    label_logit = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    loss = (lse - label_logit).mean()
    if z_loss > 0.0:
        loss = loss + z_loss * jnp.mean(lse**2)
    return loss


def next_token_loss(logits: jax.Array, tokens: jax.Array, *, z_loss: float = 0.0):
    """Shifted LM objective: predict tokens[t+1] from logits[t]."""
    return ce_loss(logits[:, :-1], tokens[:, 1:], z_loss=z_loss)
