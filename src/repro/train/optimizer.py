"""AdamW with single-pass (fused) update and pipelined gradient clipping.

Fusion: ``apply_fused=True`` routes each parameter tensor through the
Pallas fused AdamW kernel (kernels/fused_adam) — one HBM pass instead of
~8, the paper's §V-B transformation applied to the optimizer.

Pipelined clip: the PIPECG move applied to the optimizer. Standard global-
norm clipping serializes reduce(|g|^2) -> scale -> update. With
``pipelined_clip=True`` the clip scale uses the PREVIOUS step's norm (kept
in the optimizer state), so this step's reduction overlaps the update and
is consumed one step late — same one-iteration-slack trick as Alg. 2.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 0.0       # 0 = off
    pipelined_clip: bool = False  # use previous step's global norm
    apply_fused: bool = False     # Pallas fused kernel (single-device path)


class AdamWState(NamedTuple):
    m: dict
    v: dict
    step: jax.Array        # int32
    prev_norm: jax.Array   # float32, previous step's grad norm (pipelined clip)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(
        m=zeros,
        v=jax.tree.map(jnp.copy, zeros),
        step=jnp.int32(0),
        prev_norm=jnp.float32(1.0),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(tree))
    )


def _tree_update(params, grads, m, v, cfg: AdamWConfig, step, lr):
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mm, vv):
        gf = g.astype(jnp.float32)
        m_n = b1 * mm + (1 - b1) * gf
        v_n = b2 * vv + (1 - b2) * gf * gf
        mhat = m_n / bc1
        vhat = v_n / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_n, v_n

    out = jax.tree.map(upd, params, grads, m, v)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    ps = jax.tree.unflatten(treedef, [t[0] for t in flat])
    ms = jax.tree.unflatten(treedef, [t[1] for t in flat])
    vs = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return ps, ms, vs


def _fused_update(params, grads, m, v, cfg: AdamWConfig, step, lr):
    from ..kernels.fused_adam import fused_adamw

    def upd(p, g, mm, vv):
        sh = p.shape
        p2, m2, v2 = fused_adamw(
            p.reshape(-1), g.reshape(-1), mm.reshape(-1), vv.reshape(-1),
            lr=lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, wd=cfg.weight_decay,
            step=step.astype(jnp.float32),
        )
        return p2.reshape(sh), m2.reshape(sh), v2.reshape(sh)

    out = jax.tree.map(upd, params, grads, m, v)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    ps = jax.tree.unflatten(treedef, [t[0] for t in flat])
    ms = jax.tree.unflatten(treedef, [t[1] for t in flat])
    vs = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return ps, ms, vs


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig, lr=None):
    """Returns (new_params, new_state, metrics dict)."""
    step = state.step + 1
    lr = jnp.float32(cfg.lr if lr is None else lr)
    gnorm = global_norm(grads)

    if cfg.clip_norm > 0.0:
        ref = state.prev_norm if cfg.pipelined_clip else gnorm
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(ref, 1e-9))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)

    impl = _fused_update if cfg.apply_fused else _tree_update
    new_p, new_m, new_v = impl(params, grads, state.m, state.v, cfg, step, lr)
    new_state = AdamWState(m=new_m, v=new_v, step=step, prev_norm=gnorm)
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
