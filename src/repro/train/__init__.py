from .loss import ce_loss, next_token_loss
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update, global_norm
from .schedule import constant, warmup_cosine
from .train_step import TrainConfig, TrainState, abstract_train_state, init_train_state, make_train_step

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "TrainConfig",
    "TrainState",
    "abstract_train_state",
    "adamw_init",
    "adamw_update",
    "ce_loss",
    "constant",
    "global_norm",
    "init_train_state",
    "make_train_step",
    "next_token_loss",
    "warmup_cosine",
]
