"""The jit-able train step: loss -> grad -> (clipped) AdamW.

Structured so the paper's execution ideas are visible in the lowered HLO:

* ONE packed metrics vector (loss, aux, grad-norm^2, token count) — any
  cross-replica reduction of telemetry happens once per step (h2 move);
* optional pipelined clip — the clip scale consumes the PREVIOUS step's
  grad norm so the current reduction overlaps the weight update (the
  PIPECG one-step-slack move);
* optional microbatching (gradient accumulation via lax.scan) and remat
  for memory headroom at scale.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models.zoo import ModelApi
from .loss import next_token_loss
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "TrainConfig", "make_train_step", "init_train_state"]


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    remat: bool = False
    microbatches: int = 1  # gradient accumulation factor
    z_loss: float = 0.0
    aux_weight: float = 0.01  # MoE load-balance loss weight


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    step: jax.Array


def init_train_state(api: ModelApi, key: jax.Array) -> TrainState:
    params = api.init_params(key)
    return TrainState(params=params, opt=adamw_init(params), step=jnp.int32(0))


def abstract_train_state(api: ModelApi) -> TrainState:
    """ShapeDtypeStruct train state for dry-run lowering (no allocation)."""
    return jax.eval_shape(lambda: init_train_state(api, jax.random.PRNGKey(0)))


def make_train_step(
    api: ModelApi,
    tc: TrainConfig = TrainConfig(),
    lr_schedule: Optional[Callable] = None,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    cfg = api.cfg

    def loss_fn(params, batch):
        out = api.forward(params, batch, remat=tc.remat)
        if isinstance(out, tuple):
            logits, aux = out
        else:
            logits, aux = out, jnp.float32(0.0)
        nll = next_token_loss(logits, batch["tokens"], z_loss=tc.z_loss)
        return nll + tc.aux_weight * aux, (nll, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tc.microbatches <= 1:
            (loss, (nll, aux)), grads = grad_fn(params, batch)
            return loss, nll, aux, grads

        def split(x):
            b = x.shape[0]
            assert b % tc.microbatches == 0, (b, tc.microbatches)
            return x.reshape(tc.microbatches, b // tc.microbatches, *x.shape[1:])

        mb = jax.tree.map(split, batch)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc(carry, mbatch):
            loss_a, nll_a, aux_a, g_a = carry
            (loss, (nll, aux)), g = grad_fn(params, mbatch)
            g_a = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), g_a, g)
            return (loss_a + loss, nll_a + nll, aux_a + aux, g_a), None

        (loss, nll, aux, grads), _ = jax.lax.scan(
            acc, (jnp.float32(0), jnp.float32(0), jnp.float32(0), zero_g), mb
        )
        inv = 1.0 / tc.microbatches
        grads = jax.tree.map(lambda g: (g * inv).astype(jnp.float32), grads)
        return loss * inv, nll * inv, aux * inv, grads

    def train_step(state: TrainState, batch: dict):
        loss, nll, aux, grads = compute_grads(state.params, batch)
        lr = lr_schedule(state.step) if lr_schedule is not None else None
        new_params, new_opt, om = adamw_update(state.params, grads, state.opt, tc.optimizer, lr=lr)
        # ONE packed metrics vector (h2 move): single reduction point
        tokens = jnp.float32(batch["tokens"].size)
        metrics_vec = jnp.stack([loss, nll, aux, om["grad_norm"], tokens])
        metrics = {
            "loss": metrics_vec[0],
            "nll": metrics_vec[1],
            "aux": metrics_vec[2],
            "grad_norm": metrics_vec[3],
            "tokens": metrics_vec[4],
            "lr": om["lr"],
        }
        return TrainState(params=new_params, opt=new_opt, step=state.step + 1), metrics

    return train_step
