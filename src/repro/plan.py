"""Plan/execute solver API: ``repro.plan(A, ...) -> SolverPlan``.

PIPECG's economics are pay-setup-once, iterate-many: preconditioner
construction, the performance-model row decomposition, operator sharding
and tracing/compiling the iteration loop are all amortizable across every
right-hand side served against the same operator. This module is the
setup phase — the PETSc ``KSPSetUp`` / scipy ``factorized`` shape:

    p = repro.plan(A, method="h3", shards=8, M="jacobi")   # pay once
    res  = p.solve(b)                # reuses the pinned compiled loop
    many = p.solve_batched(B)        # (k, n) rhs -> ONE vmapped program
    p.describe()                     # method/engine/shard-bounds/reducer

What a plan pins at construction:

* the resolved preconditioner (``jacobi(A)`` is computed exactly once);
* for distributed methods — the perf-model ``decompose`` row boundaries,
  the device mesh, the ``ShardedDIA`` operator handle and the sharded
  inverse diagonal (nothing is re-sharded per solve);
* one jitted solve program per entry point (``solve`` / ``solve_batched``)
  with ``atol``/``rtol``/``x0`` as *traced* arguments, so changing the
  tolerance or warm-start between calls re-traces nothing.

``A`` may be any ``LinearOperator`` (``sparse.operators``): the
materialized ``DIAMatrix``/``BellMatrix``/``CSRMatrix`` formats, a dense
array, or a matrix-free :class:`~repro.sparse.FunctionOperator` (stencils
applied on the fly, Jacobian-vector products). Distributed methods still
require a ``DIAMatrix`` — their halo exchange derives from band offsets.

``repro.solve`` remains the one-shot form: a thin wrapper that fetches a
plan from a keyed cache (operator identity x configuration) and calls
``plan.solve`` — serving loops get plan reuse without holding a handle.
The single-device method registry (``register_solver``) lives here;
registered solver fns must be jit-traceable, since plans pin them inside
one compiled program.
"""
from __future__ import annotations

import hashlib
import inspect
import sys as _sys
import time
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .obs import metrics as _metrics
from .obs.trace import enabled as _obs_enabled, span as _span, trace_scope as _trace_scope
from .core import chronopoulos_cg, identity, jacobi, pcg, pipecg
from .core.distributed import (
    build_distributed_solver,
    make_solver_mesh,
    method_names,
)
from .core.perfmodel import decompose
from .core.preconditioners import IdentityPC, JacobiPC
from .core.types import SolveResult
from .sparse import balanced_rows, shard_dia, shard_vector, spmv, unshard_vector
from .sparse.formats import DIAMatrix

__all__ = [
    "plan",
    "SolverPlan",
    "register_solver",
    "solver_names",
    "get_plan",
    "operator_fingerprint",
    "plan_cache_stats",
    "clear_plan_cache",
]


def operator_fingerprint(A) -> str:
    """Stable content hash of an operator, for cross-process plan keying.

    Unlike the in-process plan cache (which keys on ``id(A)``), this
    digests the operator's *contents* — type, static metadata, and array
    bytes — so two processes that build the same matrix derive the same
    fingerprint. This is what the serving tier's plan pool
    (``serve.router``) and the warm-start manifests (``serve.warmstart``)
    key on. Operators whose identity lives in Python objects (e.g. a
    matrix-free ``FunctionOperator``'s ``fn``) fall back to an
    ``id:``-prefixed process-local fingerprint: poolable, not
    manifest-portable.
    """
    h = hashlib.sha256()
    h.update(type(A).__name__.encode())
    if isinstance(A, DIAMatrix):
        h.update(repr((A.n, A.offsets, str(A.dtype))).encode())
        h.update(np.asarray(A.data).tobytes())
    elif hasattr(A, "ndim") and not hasattr(A, "matvec"):  # dense array
        arr = np.asarray(A)
        h.update(repr((arr.shape, str(arr.dtype))).encode())
        h.update(arr.tobytes())
    else:
        try:
            leaves, treedef = jax.tree_util.tree_flatten(A)
            td = repr(treedef)
            if "0x" in td:  # object reprs with addresses: not portable
                return f"id:{id(A):x}"
            h.update(td.encode())
            for leaf in leaves:
                h.update(np.asarray(leaf).tobytes())
        except Exception:
            return f"id:{id(A):x}"
    return h.hexdigest()[:16]


def _resolve_pc(M, A):
    if M is None or M == "identity" or M == "none":
        return identity()
    if M == "jacobi":
        return jacobi(A)  # needs A.diagonal(); matrix-free operators must pass diag=
    if isinstance(M, str):
        raise ValueError(f"unknown preconditioner name {M!r} (use 'jacobi'/'identity')")
    return M


def _require_jnp_engine(method: str, engine: str) -> None:
    # honest failure instead of silently running jnp under a "pallas" label
    if engine not in ("auto", "jnp"):
        raise ValueError(
            f"method {method!r} has no {engine!r} backend (the Pallas engines "
            "apply to pipecg and the distributed methods); use engine='jnp'/'auto'"
        )


def _solve_pcg(A, b, *, M, x0, atol, rtol, maxiter, engine):
    _require_jnp_engine("pcg", engine)
    return pcg(A, b, M=M, x0=x0, atol=atol, rtol=rtol, maxiter=maxiter)


def _solve_chronopoulos(A, b, *, M, x0, atol, rtol, maxiter, engine):
    _require_jnp_engine("chronopoulos", engine)
    return chronopoulos_cg(A, b, M=M, x0=x0, atol=atol, rtol=rtol, maxiter=maxiter)


def _solve_pipecg(A, b, *, M, x0, atol, rtol, maxiter, engine,
                  replace_every=None, spmv_engine=None, tile=None, core=None):
    return pipecg(
        A, b, M=M, x0=x0, atol=atol, rtol=rtol, maxiter=maxiter,
        engine=engine, spmv_engine=spmv_engine, replace_every=replace_every,
        tile=tile, core=core,
    )


SolverFn = Callable[..., SolveResult]

_SOLVERS: Dict[str, SolverFn] = {
    "pcg": _solve_pcg,
    "chronopoulos": _solve_chronopoulos,
    "pipecg": _solve_pipecg,
}


def register_solver(name: str, fn: SolverFn, *, overwrite: bool = False) -> None:
    """Register a solve method: ``fn(A, b, *, M, x0, ...) -> SolveResult``.

    ``fn`` must be jit-traceable — plans pin it inside one compiled
    program. Raises ValueError if ``name`` is already registered, unless
    ``overwrite=True`` — silent replacement hides plug-in clashes.
    """
    if name in _SOLVERS and not overwrite:
        raise ValueError(
            f"solver {name!r} already registered; pass overwrite=True to replace it"
        )
    _SOLVERS[name] = fn


def solver_names() -> Tuple[str, ...]:
    """All method names, each exactly once, sorted."""
    return tuple(sorted(set(_SOLVERS) | set(method_names()) | {"pipecg_distributed"}))


class SolverPlan:
    """A pinned, reusable solver: setup done, only iteration remains.

    Build via :func:`repro.plan`. Thread-compatible for reads; build one
    plan per operator/configuration and fire right-hand sides at it.
    ``trace_count`` exposes how many times a solve program was traced —
    steady-state serving sits at 1 per entry point (the reuse guarantee
    the tests assert).
    """

    def __init__(self, A, *, method="pipecg", engine="auto", M="jacobi",
                 atol=1e-5, rtol=0.0, maxiter=10000, **kwargs):
        if method in method_names():  # "h1"/"h2"/"h3" aliases
            kwargs.setdefault("dist_method", method)
            method = "pipecg_distributed"
        distributed = method == "pipecg_distributed"
        if not distributed and method not in _SOLVERS:
            raise ValueError(f"unknown method {method!r}; have {solver_names()}")

        self.A = A
        self.method = method
        self.engine = engine
        self.atol = float(atol)
        self.rtol = float(rtol)
        self.maxiter = int(maxiter)
        self.n = int(A.shape[0]) if hasattr(A, "shape") else None
        self.distributed = distributed
        self._traces = 0
        self._run = None
        self._run_batched = None
        self._run_x0 = None
        self.last_report = None       # SolveReport of the latest solve (obs on)
        self._census_launches = None  # cached launches/iter census (obs on)

        with _span("plan.build", method=method, engine=engine, n=self.n,
                   distributed=distributed):
            with _span("plan.resolve_pc"):
                self.M = _resolve_pc(M, A)
            if distributed:
                self._setup_distributed(kwargs)
            else:
                self._setup_single(kwargs)
        _metrics.counter("plan.builds").inc()

    # -- setup ------------------------------------------------------------

    def _setup_single(self, kwargs):
        fn = _SOLVERS[self.method]
        params = inspect.signature(fn).parameters
        if not any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
            unknown = set(kwargs) - set(params)
            if unknown:
                raise TypeError(
                    f"method {self.method!r} does not accept {sorted(unknown)}; "
                    f"it takes {sorted(k for k in params if k not in ('A', 'b'))}"
                )
        self.kwargs = dict(kwargs)
        A, M, engine, maxiter = self.A, self.M, self.engine, self.maxiter
        call_kwargs = dict(kwargs)
        if self.method == "pipecg" and call_kwargs.get("core") is None:
            # plan-time pinning: build the operator-bound fused_iter core
            # (padded diagonal views and all) ONCE here, not per trace —
            # the while-loop body then does zero padding/reshaping and
            # repeated solves reuse the exact same kernel closure
            from .core.pipecg import pin_pipecg_core

            with _span("plan.pin_core"):
                core = pin_pipecg_core(
                    A, M, engine,
                    spmv_engine=call_kwargs.get("spmv_engine"),
                    replace_every=call_kwargs.get("replace_every"),
                    tile=call_kwargs.get("tile"),
                )
            if core is not None:
                call_kwargs["core"] = core
        self._core = call_kwargs.get("core")

        def _inner(b, x0, atol, rtol):
            self._traces += 1  # runs at trace time only
            _metrics.counter("plan.traces").inc()
            with _trace_scope(f"solve.{self.method}"):
                return fn(A, b, M=M, x0=x0, atol=atol, rtol=rtol,
                          maxiter=maxiter, engine=engine, **call_kwargs)

        self._inner = _inner
        self._run = jax.jit(_inner)

    def _setup_distributed(self, kwargs):
        dist_method = kwargs.pop("dist_method", "h3")
        shards = kwargs.pop("shards", 1)
        weights = kwargs.pop("weights", None)
        partition = kwargs.pop("partition", "rows")
        mesh = kwargs.pop("mesh", None)
        reducer = kwargs.pop("reducer", None)
        spmv_strategy = kwargs.pop("spmv", None)
        sub = kwargs.pop("sub", None)
        replace_every = int(kwargs.pop("replace_every", 0) or 0)
        if kwargs:
            raise TypeError(
                f"distributed plan does not accept {sorted(kwargs)}; it takes "
                f"['dist_method', 'mesh', 'partition', 'reducer', "
                f"'replace_every', 'shards', 'spmv', 'sub', 'weights']"
            )
        A = self.A
        if not isinstance(A, DIAMatrix):
            raise TypeError(f"distributed solve needs a DIAMatrix, got {type(A).__name__}")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if len(jax.devices()) < shards:
            raise RuntimeError(
                f"need {shards} devices but only {len(jax.devices())} visible; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={shards} before importing jax"
            )
        if partition not in ("rows", "nnz"):
            raise ValueError(f"unknown partition {partition!r} (use 'rows' or 'nnz')")
        if isinstance(self.M, JacobiPC):
            inv_diag = self.M.inv_diag
        elif isinstance(self.M, IdentityPC):
            inv_diag = jnp.ones((A.n,), A.dtype)
        else:
            raise TypeError(
                f"distributed solve supports Jacobi/identity PCs, got {type(self.M).__name__}"
            )
        # ---- the paid-once setup: decomposition, mesh, operator handle ----
        with _span("plan.decompose", shards=int(shards), partition=partition):
            if weights is not None or partition == "nnz":
                bounds = decompose(A, shards, weights=None if weights is None else np.asarray(weights))
            else:
                bounds = balanced_rows(A.n, shards)
        self.dist_method = dist_method
        self.shards = int(shards)
        self.bounds = tuple(int(x) for x in np.asarray(bounds))
        with _span("plan.shard"):
            self.mesh = mesh if mesh is not None else make_solver_mesh(shards, sub=sub)
            self.sharded = shard_dia(A, bounds)  # the reusable operator handle
        # every knob that changes the compiled program goes in here — this
        # dict is what describe() reports, and the same knobs (as user
        # kwargs) are what _plan_key freezes, so pl2/pl3/h4/sub/replace
        # variants never collide in the plan cache
        self.kwargs = {"dist_method": dist_method, "shards": self.shards,
                       "partition": partition, "reducer": reducer,
                       "spmv": spmv_strategy, "sub": sub,
                       "replace_every": replace_every}

        def _build_runner(nrhs=None):
            with _span("plan.build_solver", dist_method=dist_method,
                       nrhs=0 if nrhs is None else int(nrhs)):
                return build_distributed_solver(
                    self.sharded, mesh=self.mesh, method=dist_method,
                    engine=self.engine, maxiter=self.maxiter,
                    reducer=reducer, spmv=spmv_strategy,
                    replace_every=replace_every, nrhs=nrhs,
                )

        self._build_runner = _build_runner
        self._batched_runners = {}  # (k, with_x0) -> jitted batched program
        runner = _build_runner()
        self.pipeline_depth = runner.pipeline_depth
        self.reducer = runner.reduce_name
        self.spmv_strategy = runner.spmv_name
        inv_sh = shard_vector(inv_diag, bounds)
        self._inv_sh = inv_sh
        bounds_arr = self.bounds

        def _solve_rhs(rhs, atol, rtol) -> SolveResult:
            res = runner(shard_vector(rhs, bounds_arr), inv_sh, atol, rtol)
            return SolveResult(
                x=unshard_vector(res.x, bounds_arr), iterations=res.iterations,
                residual_norm=res.residual_norm, converged=res.converged,
                history=res.history,
            )

        def _inner0(b, atol, rtol):
            self._traces += 1
            _metrics.counter("plan.traces").inc()
            return _solve_rhs(b, atol, rtol)

        def _inner_x0(b, x0, atol, rtol):
            # nonzero warm start: solve the shifted system A d = b - A x0,
            # then x = x0 + d (no host sync, no x0==0 guard needed)
            self._traces += 1
            _metrics.counter("plan.traces").inc()
            res = _solve_rhs(b - spmv(A, x0), atol, rtol)
            return SolveResult(
                x=x0 + res.x, iterations=res.iterations,
                residual_norm=res.residual_norm, converged=res.converged,
                history=res.history,
            )

        self._run = jax.jit(_inner0)
        self._run_x0 = jax.jit(_inner_x0)

    # -- execution --------------------------------------------------------

    @property
    def trace_count(self) -> int:
        """Times any of this plan's solve programs has been (re)traced."""
        return self._traces

    def _tols(self, atol, rtol):
        return (
            jnp.float32(self.atol if atol is None else atol),
            jnp.float32(self.rtol if rtol is None else rtol),
        )

    def _execute(self, b, x0, atol, rtol) -> SolveResult:
        if self.distributed:
            if x0 is None:
                return self._run(b, atol, rtol)
            return self._run_x0(b, x0, atol, rtol)
        if x0 is None:
            x0 = jnp.zeros_like(b)
        return self._run(b, x0, atol, rtol)

    def solve(self, b, x0=None, atol: float | None = None, rtol: float | None = None) -> SolveResult:
        """Solve ``A x = b`` with this plan's pinned program.

        ``x0``/``atol``/``rtol`` are per-call and traced — varying them
        between calls does not retrace (``x0=None`` and ``x0=array`` are
        two distinct programs; steady state is still one trace each).

        With observability enabled (``repro.obs.enable()``) the solve is
        synchronized and timed, solve metrics are recorded, and a full
        :class:`~repro.obs.SolveReport` lands on ``self.last_report``.
        The disabled path is untouched: async dispatch, zero extra work,
        and a solve-loop jaxpr byte-identical to the uninstrumented one.
        """
        atol, rtol = self._tols(atol, rtol)
        if not _obs_enabled():
            return self._execute(b, x0, atol, rtol)
        traces_before = self._traces
        with _span("plan.solve", method=self.method, n=self.n) as sp:
            t0 = time.perf_counter()
            res = self._execute(b, x0, atol, rtol)
            jax.block_until_ready(res)
            elapsed = time.perf_counter() - t0
        self._record_solve(res, elapsed, b, sp, cold=self._traces > traces_before)
        return res

    def _record_solve(self, res: SolveResult, elapsed: float, b, sp, *, cold: bool) -> None:
        """Obs-enabled bookkeeping: metrics + SolveReport (host side only)."""
        from .obs.report import plan_launches_per_iteration, solve_report

        if self._census_launches is None:
            # trace-only census, cached per plan: kernel launches per
            # iteration of the pinned loop (the fusion trajectory metric)
            self._census_launches = plan_launches_per_iteration(self, b)
        report = solve_report(
            self, res, elapsed_s=elapsed, launches=self._census_launches, cold_start=cold
        )
        self.last_report = report
        if sp is not None:
            sp.attrs.update(iterations=report.iterations, time_s=elapsed,
                            converged=report.converged, cold_start=cold)
        _metrics.counter("plan.solves").inc()
        if cold:
            # first solve through a fresh program: wall time is trace +
            # compile + solve; keep it out of the steady-state histogram
            _metrics.counter("plan.cold_solves").inc()
            _metrics.histogram("plan.cold_solve_time_s").record(elapsed)
        else:
            _metrics.histogram("plan.solve_time_s").record(elapsed)
        _metrics.histogram("plan.solve_iterations").record(report.iterations)
        if not report.converged:
            _metrics.counter("plan.solves_unconverged").inc()
        if report.rr_events:
            _metrics.counter("plan.rr_events").inc(report.rr_events)

    def solve_batched(self, B, x0=None, atol: float | None = None, rtol: float | None = None) -> SolveResult:
        """Solve a batch of rhs, shape (k, n) -> SolveResult with leading k.

        Single-device methods run as ONE vmapped XLA program (per-lane
        results are exact; wall-clock is set by the slowest rhs).
        Distributed methods also run as ONE program: the solver loop is
        vmapped *inside* the shard_map block, so each global reduction
        carries the whole batch's partials (k-fold useful work per
        reduction — see docs/distributed.md). The batched program is
        built+compiled once per batch size k and cached on the plan.
        With observability enabled the batch is synchronized/timed and
        batch metrics are recorded.
        """
        if not _obs_enabled():
            return self._execute_batched(B, x0, atol, rtol)
        traces_before = self._traces
        with _span("plan.solve_batched", k=int(B.shape[0]), n=self.n) as sp:
            t0 = time.perf_counter()
            res = self._execute_batched(B, x0, atol, rtol)
            jax.block_until_ready(res)
            elapsed = time.perf_counter() - t0
        from .obs.report import iterations_from_history, plan_launches_per_iteration, solve_report

        cold = self._traces > traces_before
        iters = iterations_from_history(res.history)
        if self._census_launches is None and B.shape[0]:
            self._census_launches = plan_launches_per_iteration(self, B[0])
        self.last_report = solve_report(
            self, res, elapsed_s=elapsed, launches=self._census_launches, cold_start=cold
        )
        if sp is not None:
            sp.attrs.update(time_s=elapsed, cold_start=cold,
                            iterations_max=int(np.max(iters)) if len(iters) else 0)
        _metrics.counter("plan.batched_solves").inc()
        _metrics.counter("plan.batched_rhs").inc(int(B.shape[0]))
        _metrics.histogram("plan.batch_size").record(int(B.shape[0]))
        _metrics.histogram(
            "plan.cold_solve_time_s" if cold else "plan.solve_time_s"
        ).record(elapsed)
        for it in np.asarray(iters).ravel():
            _metrics.histogram("plan.solve_iterations").record(int(it))
        return res

    def _batched_distributed(self, k: int, with_x0: bool):
        """The (k rhs, warm-start?) batched program, built+jitted once per k.

        One shard_map program for the whole batch: the solver loop is
        vmapped INSIDE the block (core.distributed), so every global
        reduction carries k systems' partials — no Python per-rhs loop.
        """
        cached = self._batched_runners.get((k, with_x0))
        if cached is not None:
            return cached
        runner = self._build_runner(nrhs=k)
        A, bounds, inv_sh = self.A, self.bounds, self._inv_sh

        def _solve_rhs_batch(B, atol, rtol) -> SolveResult:
            from .sparse import shard_vectors, unshard_vectors

            res = runner(shard_vectors(B, bounds), inv_sh, atol, rtol)
            return SolveResult(
                x=unshard_vectors(res.x, bounds), iterations=res.iterations,
                residual_norm=res.residual_norm, converged=res.converged,
                history=res.history,
            )

        if with_x0:
            def _inner(B, X0, atol, rtol):
                # warm starts via the shifted systems A d_k = b_k - A x0_k
                self._traces += 1
                _metrics.counter("plan.traces").inc()
                res = _solve_rhs_batch(B - jax.vmap(lambda v: spmv(A, v))(X0), atol, rtol)
                return SolveResult(
                    x=X0 + res.x, iterations=res.iterations,
                    residual_norm=res.residual_norm, converged=res.converged,
                    history=res.history,
                )
        else:
            def _inner(B, atol, rtol):
                self._traces += 1
                _metrics.counter("plan.traces").inc()
                return _solve_rhs_batch(B, atol, rtol)

        jitted = jax.jit(_inner)
        self._batched_runners[(k, with_x0)] = jitted
        return jitted

    def _execute_batched(self, B, x0, atol, rtol) -> SolveResult:
        atol, rtol = self._tols(atol, rtol)
        if self.distributed:
            run = self._batched_distributed(int(B.shape[0]), x0 is not None)
            if x0 is None:
                return run(B, atol, rtol)
            return run(B, x0, atol, rtol)
        if self._run_batched is None:
            self._run_batched = jax.jit(jax.vmap(self._inner, in_axes=(0, 0, None, None)))
        if x0 is None:
            x0 = jnp.zeros_like(B)
        return self._run_batched(B, x0, atol, rtol)

    def describe(self) -> dict:
        """Introspection: what this plan pinned at setup."""
        d = {
            "method": self.kwargs.get("dist_method", self.method) if self.distributed else self.method,
            "engine": self.engine,
            "n": self.n,
            "dtype": str(getattr(self.A, "dtype", "?")),
            "operator": type(self.A).__name__,
            "preconditioner": type(self.M).__name__,
            "atol": self.atol,
            "rtol": self.rtol,
            "maxiter": self.maxiter,
            "distributed": self.distributed,
            "trace_count": self._traces,
        }
        if self.distributed:
            d.update(
                shards=self.shards,
                shard_bounds=self.bounds,
                rows_per_shard=tuple(int(x) for x in np.diff(self.bounds)),
                reducer=self.reducer,            # override-resolved, not the
                spmv_strategy=self.spmv_strategy,  # method's registered default
                mesh_axes=tuple(self.mesh.axis_names),
                pipeline_depth=self.pipeline_depth,
                sub=self.kwargs.get("sub"),
                replace_every=self.kwargs.get("replace_every", 0),
            )
        else:
            d.update({k: v for k, v in self.kwargs.items() if v is not None})
            if self.method == "pipecg":
                from .core.pipecg import _resolve_config

                try:
                    cn, se, rep = _resolve_config(
                        self.A, self.M, self.engine,
                        self.kwargs.get("spmv_engine"),
                        self.kwargs.get("replace_every"),
                        getattr(self, "_core", None),
                    )
                except (TypeError, ValueError):
                    pass
                else:
                    d.update(core=cn, spmv_engine=se, replace_every=rep)
        return d

    def config(self) -> dict:
        """JSON-able rebuild recipe: ``plan(A, **cfg)`` on an operator with
        the same contents reproduces this plan (same ``describe()``, same
        pool key). This is the manifest-export hook the serving tier's
        cross-process warm start (``serve.warmstart``) serializes; it
        raises for plans whose configuration holds live Python objects
        (custom preconditioner / pinned core / explicit mesh) — those
        cannot be rebuilt from JSON.
        """
        if isinstance(self.M, JacobiPC):
            M = "jacobi"
        elif isinstance(self.M, IdentityPC):
            M = "identity"
        else:
            raise ValueError(
                f"plan with a custom preconditioner object "
                f"({type(self.M).__name__}) is not manifest-serializable; "
                "use M='jacobi'/'identity'"
            )
        cfg = {
            "method": self.method,
            "engine": self.engine,
            "M": M,
            "atol": self.atol,
            "rtol": self.rtol,
            "maxiter": self.maxiter,
        }
        for k, v in self.kwargs.items():
            if v is None:
                continue
            if not isinstance(v, (bool, int, float, str)):
                raise ValueError(
                    f"plan kwarg {k}={type(v).__name__} is not "
                    "manifest-serializable (pass plain scalars/strings)"
                )
            cfg[k] = v
        return cfg

    def __repr__(self) -> str:
        cfg = ", ".join(f"{k}={v!r}" for k, v in self.describe().items())
        return f"SolverPlan({cfg})"


def plan(A, method: str = "pipecg", engine: str = "auto", M="jacobi",
         *, atol: float = 1e-5, rtol: float = 0.0, maxiter: int = 10000,
         **kwargs) -> SolverPlan:
    """Build a reusable :class:`SolverPlan` for ``A`` (see module docstring).

    Keyword arguments mirror ``repro.solve``: ``replace_every``/
    ``spmv_engine``/``tile`` (pipecg — a pipecg plan with
    ``engine="fused_iter"`` builds the whole-iteration fused core and its
    padded operator views once, right here), ``shards``/``weights``/
    ``partition``/``mesh``/``reducer``/``spmv``/``sub``/``replace_every``
    (distributed methods — ``sub`` builds the 2-D hierarchical mesh the
    "h4" reducer needs; see docs/distributed.md for the selection matrix). ``atol``/``rtol`` set
    the plan's *defaults* — ``plan.solve(b, atol=...)`` overrides per
    call without retracing.
    """
    return SolverPlan(A, method=method, engine=engine, M=M,
                      atol=atol, rtol=rtol, maxiter=maxiter, **kwargs)


# ---------------------------------------------------------------------------
# the keyed plan cache behind one-shot ``repro.solve``
# ---------------------------------------------------------------------------

_PLAN_CACHE: "OrderedDict[tuple, SolverPlan]" = OrderedDict()
_PLAN_CACHE_MAX = 16
_CACHE_STATS = {"hits": 0, "misses": 0, "uncachable": 0}


def _freeze(v):
    if v is None or isinstance(v, (bool, int, float, str, bytes)):
        return v
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if hasattr(v, "ravel"):  # numpy / jax arrays (e.g. weights)
        return ("arr",) + tuple(np.asarray(v).ravel().tolist())
    return ("id", id(v))  # identity-keyed; the plan keeps the object alive


def _plan_key(A, method, engine, M, maxiter, kwargs):
    Mk = M if (M is None or isinstance(M, str)) else ("id", id(M))
    items = tuple((k, _freeze(kwargs[k])) for k in sorted(kwargs))
    key = (id(A), method, engine, Mk, int(maxiter), items)
    try:
        hash(key)
    except TypeError:
        return None
    return key


def get_plan(A, *, method="pipecg", engine="auto", M="jacobi",
             maxiter: int = 10000, **kwargs) -> SolverPlan:
    """Fetch-or-build a cached plan keyed on operator identity x config.

    Identity keys (``id(A)``, ``id(M)``, ...) are safe because the cached
    plan holds strong references to those exact objects — an id cannot be
    reused while its entry lives. A hit is verified with ``is`` against
    the live operator; eviction is LRU at {max} entries.
    """
    key = _plan_key(A, method, engine, M, maxiter, kwargs)
    if key is not None:
        cached = _PLAN_CACHE.get(key)
        if cached is not None and cached.A is A:
            _PLAN_CACHE.move_to_end(key)
            _CACHE_STATS["hits"] += 1
            _metrics.counter("plan_cache.hits").inc()
            return cached
        _CACHE_STATS["misses"] += 1
        _metrics.counter("plan_cache.misses").inc()
    else:
        _CACHE_STATS["uncachable"] += 1
        _metrics.counter("plan_cache.uncachable").inc()
    p = plan(A, method=method, engine=engine, M=M, maxiter=maxiter, **kwargs)
    if key is not None:
        _PLAN_CACHE[key] = p
        while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
            _PLAN_CACHE.popitem(last=False)
        _metrics.gauge("plan_cache.size").set(len(_PLAN_CACHE))
    return p


if get_plan.__doc__:
    get_plan.__doc__ = get_plan.__doc__.replace("{max}", str(_PLAN_CACHE_MAX))


def plan_cache_stats() -> dict:
    """Hit/miss/uncachable counters + current size of the plan cache."""
    return dict(_CACHE_STATS, size=len(_PLAN_CACHE))


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    for k in _CACHE_STATS:
        _CACHE_STATS[k] = 0


# ``repro.plan`` names both this module and the entry-point function; any
# ``import repro.plan`` sets the package attribute to the module, which
# would otherwise shadow the callable. Making the module itself callable
# (delegating to :func:`plan`) keeps ``repro.plan(A, ...)`` working under
# every import order while ``repro.plan.SolverPlan`` etc. stay reachable.
class _CallableModule(_sys.modules[__name__].__class__):
    __call__ = staticmethod(plan)


_sys.modules[__name__].__class__ = _CallableModule
