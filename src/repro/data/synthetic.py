"""Deterministic synthetic token pipeline with host-side prefetch.

Determinism contract: the batch for (seed, step) is a pure function — a
restarted or re-elastically-sharded job consumes byte-identical data, which
is what makes checkpoint/restart exact (runtime/fault_tolerance.py).

Prefetch: a background thread keeps ``depth`` batches ready (generation
overlaps device compute — the paper's hide-the-transfer discipline applied
to the input pipeline).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig

__all__ = ["SyntheticConfig", "batch_for_step", "prefetch_batches"]


@dataclass(frozen=True)
class SyntheticConfig:
    batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def batch_for_step(dc: SyntheticConfig, step: int, cfg: Optional[ArchConfig] = None) -> dict:
    """Markov-ish token stream (not uniform noise, so loss can decrease)."""
    rng = _rng_for(dc.seed, step)
    B, T, V = dc.batch, dc.seq_len, dc.vocab_size
    # piecewise-linear token process: next ~ prev + small step (mod V)
    start = rng.integers(0, V, size=(B, 1))
    steps = rng.integers(-3, 4, size=(B, T))
    tokens = (start + np.cumsum(steps, axis=1)) % V
    out = {"tokens": tokens.astype(np.int32)}
    if cfg is not None and cfg.family == "encdec":
        out["frames"] = rng.standard_normal((B, cfg.enc_seq, cfg.d_model)).astype(np.float32) * 0.02
    if cfg is not None and cfg.family == "vlm":
        out["img_feats"] = rng.standard_normal((B, cfg.n_img_tokens, cfg.d_model)).astype(np.float32) * 0.02
    return out


def prefetch_batches(
    dc: SyntheticConfig,
    start_step: int,
    n_steps: int,
    cfg: Optional[ArchConfig] = None,
    depth: int = 2,
    place=None,
) -> Iterator[dict]:
    """Host-prefetched iterator; ``place`` optionally maps a host batch to
    device arrays (e.g. functools.partial(jax.device_put, device=sharding))."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = object()

    def producer():
        for s in range(start_step, start_step + n_steps):
            b = batch_for_step(dc, s, cfg)
            if place is not None:
                b = place(b)
            q.put(b)
        q.put(stop)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
