from .synthetic import SyntheticConfig, batch_for_step, prefetch_batches

__all__ = ["SyntheticConfig", "batch_for_step", "prefetch_batches"]
