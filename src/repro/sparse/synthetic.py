"""Synthetic SPD matrices standing in for the paper's SuiteSparse set.

No network access is available, so the seven Table-I matrices are replaced
by synthetic banded SPD matrices matched in N and nnz/N (and displayed under
the same names). The generator draws random banded symmetric off-diagonals
and makes the matrix strictly diagonally dominant, hence SPD.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .formats import DIAMatrix

__all__ = ["synthetic_spd_dia", "table1_matrix", "TABLE1"]

# name -> (N, nnz per row) from Table I of the paper.
TABLE1: dict[str, tuple[int, float]] = {
    "bcsstk15": (3948, 29.84),
    "gyro": (17361, 58.81),
    "boneS01": (127224, 52.78),
    "hood": (220542, 48.82),
    "offshore": (259789, 16.33),
    "Serena": (1391349, 46.38),
    "Queen_4147": (4147110, 79.45),
}


def synthetic_spd_dia(
    n: int,
    nnz_per_row: float,
    seed: int = 0,
    bandwidth: int | None = None,
    sigma: float = 1.0,
    dtype=jnp.float32,
) -> DIAMatrix:
    """Random banded SPD matrix in DIA form with ~``nnz_per_row`` band width.

    The band is split between near diagonals (cache-local, stencil-like) and
    a few far diagonals (to exercise halo widths), mirroring the profile of
    FEM matrices in the paper's table.
    """
    rng = np.random.default_rng(seed)
    n_pairs = max(1, int(round((nnz_per_row - 1) / 2)))
    bw = bandwidth if bandwidth is not None else max(n_pairs * 2, min(n // 8 + 1, 4 * n_pairs))
    bw = min(bw, n - 1)
    near = [o for o in range(1, n_pairs // 2 + 2)][: max(1, n_pairs // 2)]
    remaining = n_pairs - len(near)
    far_pool = np.arange(max(near) + 1, bw + 1)
    if remaining > 0 and far_pool.size > 0:
        far = sorted(rng.choice(far_pool, size=min(remaining, far_pool.size), replace=False).tolist())
    else:
        far = []
    pos_offsets = sorted(set(near + far))

    offsets = sorted({0, *pos_offsets, *(-o for o in pos_offsets)})
    pos = {o: j for j, o in enumerate(offsets)}
    data = np.zeros((len(offsets), n), dtype=np.float64)

    for o in pos_offsets:
        vals = rng.uniform(0.1, 1.0, size=n - o) * rng.choice([-1.0, 1.0], size=n - o)
        # A[i, i+o] = vals[i] for i in [0, n-o)
        data[pos[o], : n - o] = vals
        # symmetry: A[i, i-o] = A[i-o, i] -> data[-o][i] = data[o][i-o]
        data[pos[-o], o:n] = vals

    # strict diagonal dominance -> SPD
    data[pos[0]] = np.abs(data).sum(axis=0) + sigma
    return DIAMatrix(jnp.asarray(data, dtype=dtype), tuple(offsets), n)


def table1_matrix(name: str, scale: float = 1.0, seed: int = 0, dtype=jnp.float32) -> DIAMatrix:
    """Synthetic analogue of a Table-I matrix, optionally scaled down in N.

    ``scale`` < 1 shrinks N (for CPU-sized tests/benchmarks) while keeping
    nnz/N, which is what drives the method crossover points in the paper.
    """
    if name not in TABLE1:
        raise KeyError(f"unknown Table-I matrix {name!r}; have {sorted(TABLE1)}")
    n_full, nnz_per_row = TABLE1[name]
    n = max(64, int(n_full * scale))
    return synthetic_spd_dia(n, nnz_per_row, seed=seed, dtype=dtype)
