"""Row partitioning + 2-D (local/halo) decomposition — paper §IV-C.

The paper's Hybrid-PIPECG-3 decomposes rows so that nnz is proportional to
measured device throughput (1-D), then splits each part's nnz into
``nnz1`` (columns resident on the device) and ``nnz2`` (columns that arrive
via the m-vector exchange), overlapping SPMV-part-1 with the exchange (2-D).

On the TPU mesh the same structure becomes:

* ``balanced_nnz`` — cut rows so per-shard nnz matches per-device weights
  (uniform weights on a healthy pod; remeasured weights = straggler
  mitigation).
* ``ShardedDIA`` — per-shard banded blocks padded to a common row count so
  they stack into a leading device axis for ``shard_map``; the local/halo
  column split is implicit in the band structure (columns within the shard's
  row range = local block = "nnz1"; boundary strips = "nnz2").

Shards exchange only boundary slabs of width ``bandwidth`` with ring
neighbors (``collective_permute``), and the local SPMV runs while the slabs
are in flight.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import DIAMatrix

__all__ = [
    "balanced_rows",
    "balanced_nnz",
    "ShardedDIA",
    "shard_dia",
    "shard_vector",
    "unshard_vector",
    "shard_vectors",
    "unshard_vectors",
    "partition_stats",
]


def balanced_rows(n: int, parts: int) -> np.ndarray:
    """Equal-row boundaries: (parts+1,) with boundaries[0]=0, [-1]=n."""
    base = n // parts
    rem = n % parts
    sizes = np.full(parts, base, dtype=np.int64)
    sizes[:rem] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


def balanced_nnz(row_nnz: np.ndarray, parts: int, weights: np.ndarray | None = None) -> np.ndarray:
    """Cut rows so each part's nnz ~ proportional to its weight.

    This is the paper's performance-model decomposition: ``weights`` are
    relative device speeds (s_dev / sum(s)); uniform if None.
    Returns row boundaries (parts+1,).
    """
    n = len(row_nnz)
    if weights is None:
        weights = np.ones(parts)
    weights = np.asarray(weights, dtype=np.float64)
    weights = weights / weights.sum()
    cum = np.concatenate([[0], np.cumsum(row_nnz, dtype=np.float64)])
    total = cum[-1]
    targets = np.cumsum(weights) * total
    bounds = np.searchsorted(cum, targets[:-1], side="left")
    bounds = np.clip(bounds, 1, n - 1)
    # enforce strictly increasing (each part >= 1 row when possible)
    for i in range(1, len(bounds)):
        if bounds[i] <= bounds[i - 1]:
            bounds[i] = min(bounds[i - 1] + 1, n - 1)
    return np.concatenate([[0], bounds, [n]]).astype(np.int64)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["data", "rows_valid"],
    meta_fields=["offsets", "n", "rows_max", "boundaries"],
)
@dataclass(frozen=True)
class ShardedDIA:
    """DIA matrix split into P row blocks stacked on a leading device axis.

    ``data[p, j, i]`` = A[boundaries[p]+i, boundaries[p]+i+offsets[j]] for
    i < rows_valid[p]; padded rows are identity (diag=1) so padded vector
    entries stay 0 through the solve.
    """

    data: jax.Array  # (P, n_diags, rows_max)
    rows_valid: jax.Array  # (P,) int32
    offsets: Tuple[int, ...]
    n: int
    rows_max: int
    boundaries: Tuple[int, ...]

    @property
    def n_shards(self) -> int:
        return self.data.shape[0]

    @property
    def bandwidth(self) -> int:
        return max(abs(o) for o in self.offsets)

    def diagonal_sharded(self) -> jax.Array:
        j = self.offsets.index(0)
        return self.data[:, j, :]  # (P, rows_max)


def shard_dia(dia: DIAMatrix, boundaries: np.ndarray) -> ShardedDIA:
    """Split a DIA matrix into padded row blocks along ``boundaries``."""
    P = len(boundaries) - 1
    sizes = np.diff(boundaries)
    rows_max = int(sizes.max())
    hw = dia.bandwidth
    if int(sizes.min()) < hw and not (sizes == rows_max).all():
        # equal shards are fine at any bandwidth: the halo SPMV walks
        # ceil(hw/rows) ring hops; only the unequal (performance-model)
        # partition is restricted to single-hop neighbor exchange
        raise ValueError(
            f"smallest shard ({int(sizes.min())}) < bandwidth ({hw}): "
            f"unequal shards support single-hop halo only (use balanced_rows "
            f"for the multi-hop path)"
        )
    k = dia.n_diags
    data_np = np.asarray(dia.data)
    out = np.zeros((P, k, rows_max), dtype=data_np.dtype)
    j0 = dia.offsets.index(0)
    for p in range(P):
        lo, hi = int(boundaries[p]), int(boundaries[p + 1])
        out[p, :, : hi - lo] = data_np[:, lo:hi]
        out[p, j0, hi - lo :] = 1.0  # identity padding rows
    return ShardedDIA(
        data=jnp.asarray(out),
        rows_valid=jnp.asarray(sizes, dtype=jnp.int32),
        offsets=dia.offsets,
        n=dia.n,
        rows_max=rows_max,
        boundaries=tuple(int(b) for b in boundaries),
    )


def shard_vector(x: jax.Array, boundaries) -> jax.Array:
    """(n,) -> (P, rows_max) padded with zeros to match ShardedDIA blocks."""
    boundaries = np.asarray(boundaries)
    P = len(boundaries) - 1
    sizes = np.diff(boundaries)
    rows_max = int(sizes.max())
    out = jnp.zeros((P, rows_max), dtype=x.dtype)
    for p in range(P):
        lo, hi = int(boundaries[p]), int(boundaries[p + 1])
        out = out.at[p, : hi - lo].set(x[lo:hi])
    return out


def shard_vectors(xs: jax.Array, boundaries) -> jax.Array:
    """(k, n) rhs batch -> (P, k, rows_max), the batched-solver layout.

    Shard axis leads (matches ShardedDIA / shard_map in_specs); the rhs
    axis sits between shard and row so each device holds its k local row
    blocks contiguously.
    """
    return jnp.stack([shard_vector(x, boundaries) for x in xs], axis=1)


def unshard_vectors(xs: jax.Array, boundaries) -> jax.Array:
    """(P, k, rows_max) -> (k, n): inverse of shard_vectors."""
    k = xs.shape[1]
    return jnp.stack([unshard_vector(xs[:, j], boundaries) for j in range(k)])


def unshard_vector(xs: jax.Array, boundaries) -> jax.Array:
    boundaries = np.asarray(boundaries)
    P = len(boundaries) - 1
    parts = []
    for p in range(P):
        lo, hi = int(boundaries[p]), int(boundaries[p + 1])
        parts.append(xs[p, : hi - lo])
    return jnp.concatenate(parts)


def partition_stats(dia: DIAMatrix, boundaries: np.ndarray) -> dict:
    """nnz1/nnz2 accounting per shard — the paper's 2-D decomposition view."""
    data = np.asarray(dia.data)
    stats = {"shards": []}
    for p in range(len(boundaries) - 1):
        lo, hi = int(boundaries[p]), int(boundaries[p + 1])
        nnz1 = nnz2 = 0
        for j, o in enumerate(dia.offsets):
            nz = np.count_nonzero(data[j, lo:hi])
            rows = np.arange(lo, hi)
            cols = rows + o
            local = (cols >= lo) & (cols < hi)
            valid = (cols >= 0) & (cols < dia.n) & (data[j, lo:hi] != 0)
            nnz1 += int(np.count_nonzero(local & valid))
            nnz2 += int(np.count_nonzero(~local & valid))
            del nz
        stats["shards"].append({"rows": hi - lo, "nnz_local": nnz1, "nnz_halo": nnz2})
    return stats
