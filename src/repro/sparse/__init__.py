from .formats import (
    BellMatrix,
    CSRHost,
    DIAMatrix,
    bell_from_csr,
    csr_from_dia,
    csr_from_dense,
    dia_from_csr,
)
from .partition import (
    ShardedDIA,
    balanced_nnz,
    balanced_rows,
    partition_stats,
    shard_dia,
    shard_vector,
    unshard_vector,
)
from .spmv import register_spmv, shifted, spmv, spmv_bell, spmv_dia, spmv_engines
from .stencil import poisson7, poisson27, poisson125, poisson_dia, stencil_offsets
from .synthetic import TABLE1, synthetic_spd_dia, table1_matrix

__all__ = [
    "BellMatrix",
    "CSRHost",
    "DIAMatrix",
    "ShardedDIA",
    "TABLE1",
    "balanced_nnz",
    "balanced_rows",
    "bell_from_csr",
    "csr_from_dense",
    "csr_from_dia",
    "dia_from_csr",
    "partition_stats",
    "poisson7",
    "poisson27",
    "poisson125",
    "poisson_dia",
    "register_spmv",
    "shard_dia",
    "shard_vector",
    "shifted",
    "spmv",
    "spmv_bell",
    "spmv_dia",
    "spmv_engines",
    "stencil_offsets",
    "synthetic_spd_dia",
    "table1_matrix",
    "unshard_vector",
]
