"""Sparse matrix containers used across the solver stack.

TPU-friendly formats:

* ``DIAMatrix`` — diagonal (banded) storage. The natural format for the
  paper's Poisson stencil matrices (7/27/125-point): every diagonal is a
  dense vector, SPMV is a sum of shifted elementwise multiplies that maps
  directly onto the VPU with no gathers. Offsets are static metadata so the
  set of shifts is known at trace time.
* ``BellMatrix`` — Block-ELLPACK: every row padded to a fixed number of
  slots ``R`` (column index + value). General sparsity with a regular,
  vectorizable layout (the TPU answer to CSR's ragged rows).
* ``CSRMatrix`` — device CSR in expanded (COO-row) form: per-entry row ids
  so SPMV is a gather + segment-sum with no ragged indexing. The general
  fallback format when a matrix has no band/slot structure to exploit.
* ``CSRHost`` — host-side (numpy) CSR used only for construction,
  partitioning and conversion; never traced.

All device containers are registered dataclass pytrees: array leaves are
data, shapes/offsets are static metadata. Each carries a ``matvec``
adapter (routed through the ``sparse.spmv`` engine registry) so it
satisfies the ``LinearOperator`` protocol the solvers are written against.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DIAMatrix",
    "BellMatrix",
    "CSRMatrix",
    "CSRHost",
    "dia_from_csr",
    "bell_from_csr",
    "csr_from_dia",
    "csr_device_from_host",
]


@partial(jax.tree_util.register_dataclass, data_fields=["data"], meta_fields=["offsets", "n"])
@dataclass(frozen=True)
class DIAMatrix:
    """Banded matrix in diagonal storage.

    ``data[j, i] = A[i, i + offsets[j]]`` (row-major banded convention).
    Entries whose column falls outside ``[0, n)`` are stored as 0 and never
    read. ``offsets`` is a static tuple so SPMV unrolls into static shifts.
    """

    data: jax.Array  # (n_diags, n)
    offsets: Tuple[int, ...]
    n: int

    @property
    def n_diags(self) -> int:
        return len(self.offsets)

    @property
    def bandwidth(self) -> int:
        return max(abs(o) for o in self.offsets)

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def shape(self):
        return (self.n, self.n)

    def diagonal(self) -> jax.Array:
        j = self.offsets.index(0)
        return self.data[j]

    def nnz(self) -> int:
        """Structural nnz (band entries inside the matrix)."""
        total = 0
        for o in self.offsets:
            total += self.n - abs(o)
        return total

    def with_dtype(self, dtype) -> "DIAMatrix":
        return DIAMatrix(self.data.astype(dtype), self.offsets, self.n)

    def matvec(self, x: jax.Array) -> jax.Array:
        from .spmv import spmv  # lazy: formats is imported by spmv

        return spmv(self, x)


@partial(jax.tree_util.register_dataclass, data_fields=["cols", "vals"], meta_fields=["n"])
@dataclass(frozen=True)
class BellMatrix:
    """Block-ELLPACK: fixed ``R`` slots per row.

    Padding slots point at column 0 with value 0 (safe gather target).
    """

    cols: jax.Array  # (n, R) int32
    vals: jax.Array  # (n, R)
    n: int

    @property
    def slots_per_row(self) -> int:
        return self.cols.shape[1]

    @property
    def dtype(self):
        return self.vals.dtype

    @property
    def shape(self):
        return (self.n, self.n)

    def diagonal(self) -> jax.Array:
        row = jnp.arange(self.n, dtype=self.cols.dtype)[:, None]
        mask = self.cols == row
        return (self.vals * mask).sum(axis=1)

    def nnz(self) -> int:
        return int(self.cols.shape[0] * self.cols.shape[1])

    def with_dtype(self, dtype) -> "BellMatrix":
        return BellMatrix(self.cols, self.vals.astype(dtype), self.n)

    def matvec(self, x: jax.Array) -> jax.Array:
        from .spmv import spmv

        return spmv(self, x)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["rows", "cols", "vals"],
    meta_fields=["n"],
)
@dataclass(frozen=True)
class CSRMatrix:
    """Device CSR in expanded (COO-row) form.

    ``rows``/``cols``/``vals`` are parallel (nnz,) arrays sorted by row —
    the layout segment-sum SPMV wants (``indices_are_sorted=True``), with
    no ragged ``indptr`` indexing on device. Build via
    :func:`csr_device_from_host`.
    """

    rows: jax.Array  # (nnz,) int32, sorted ascending
    cols: jax.Array  # (nnz,) int32
    vals: jax.Array  # (nnz,)
    n: int

    @property
    def dtype(self):
        return self.vals.dtype

    @property
    def shape(self):
        return (self.n, self.n)

    def nnz(self) -> int:
        return int(self.vals.shape[0])

    def diagonal(self) -> jax.Array:
        on_diag = self.rows == self.cols
        return jnp.zeros((self.n,), self.vals.dtype).at[self.rows].add(
            jnp.where(on_diag, self.vals, 0)
        )

    def with_dtype(self, dtype) -> "CSRMatrix":
        return CSRMatrix(self.rows, self.cols, self.vals.astype(dtype), self.n)

    def matvec(self, x: jax.Array) -> jax.Array:
        from .spmv import spmv

        return spmv(self, x)


def csr_device_from_host(csr: "CSRHost") -> CSRMatrix:
    """Expand host CSR (indptr) into the device COO-row layout."""
    rows = np.repeat(np.arange(csr.n, dtype=np.int32), csr.row_nnz())
    return CSRMatrix(
        rows=jnp.asarray(rows),
        cols=jnp.asarray(csr.indices, dtype=jnp.int32),
        vals=jnp.asarray(csr.data),
        n=csr.n,
    )


@dataclass(frozen=True)
class CSRHost:
    """Host-side CSR (numpy). Construction / partitioning only."""

    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (nnz,) int64
    data: np.ndarray  # (nnz,)
    n: int

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def diagonal(self) -> np.ndarray:
        d = np.zeros(self.n, dtype=self.data.dtype)
        for i in range(self.n):
            lo, hi = self.indptr[i], self.indptr[i + 1]
            cols = self.indices[lo:hi]
            hit = np.nonzero(cols == i)[0]
            if hit.size:
                d[i] = self.data[lo + hit[0]]
        return d

    def to_dense(self) -> np.ndarray:
        A = np.zeros((self.n, self.n), dtype=self.data.dtype)
        for i in range(self.n):
            lo, hi = self.indptr[i], self.indptr[i + 1]
            A[i, self.indices[lo:hi]] = self.data[lo:hi]
        return A


def csr_from_dense(A: np.ndarray) -> CSRHost:
    n = A.shape[0]
    indptr = [0]
    indices = []
    data = []
    for i in range(n):
        nz = np.nonzero(A[i])[0]
        indices.extend(nz.tolist())
        data.extend(A[i, nz].tolist())
        indptr.append(len(indices))
    return CSRHost(
        np.asarray(indptr, dtype=np.int64),
        np.asarray(indices, dtype=np.int64),
        np.asarray(data, dtype=A.dtype),
        n,
    )


def dia_from_csr(csr: CSRHost) -> DIAMatrix:
    """Convert host CSR to DIA. Offsets = every distinct (col - row)."""
    n = csr.n
    rows = np.repeat(np.arange(n), csr.row_nnz())
    offs = csr.indices - rows
    uniq = np.unique(offs)
    data = np.zeros((len(uniq), n), dtype=csr.data.dtype)
    pos = {int(o): j for j, o in enumerate(uniq)}
    for r, c, v in zip(rows, csr.indices, csr.data):
        data[pos[int(c - r)], r] = v
    return DIAMatrix(jnp.asarray(data), tuple(int(o) for o in uniq), n)


def csr_from_dia(dia: DIAMatrix) -> CSRHost:
    n = dia.n
    data_np = np.asarray(dia.data)
    rows_all, cols_all, vals_all = [], [], []
    for j, o in enumerate(dia.offsets):
        lo = max(0, -o)
        hi = min(n, n - o)
        r = np.arange(lo, hi)
        rows_all.append(r)
        cols_all.append(r + o)
        vals_all.append(data_np[j, lo:hi])
    rows = np.concatenate(rows_all)
    cols = np.concatenate(cols_all)
    vals = np.concatenate(vals_all)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    keep = vals != 0
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRHost(indptr, cols.astype(np.int64), vals, n)


def bell_from_csr(csr: CSRHost, slots_per_row: int | None = None) -> BellMatrix:
    n = csr.n
    row_nnz = csr.row_nnz()
    R = int(slots_per_row or row_nnz.max() or 1)
    if row_nnz.max() > R:
        raise ValueError(f"slots_per_row={R} < max row nnz {row_nnz.max()}")
    cols = np.zeros((n, R), dtype=np.int32)
    vals = np.zeros((n, R), dtype=csr.data.dtype)
    for i in range(n):
        lo, hi = csr.indptr[i], csr.indptr[i + 1]
        k = hi - lo
        cols[i, :k] = csr.indices[lo:hi]
        vals[i, :k] = csr.data[lo:hi]
    return BellMatrix(jnp.asarray(cols), jnp.asarray(vals), n)
