"""Stencil matrix generators (the paper's Poisson problems).

The paper evaluates on 125-point Poisson matrices (5x5x5 stencil,
nnz/N ~ 122) plus SuiteSparse matrices. We generate the stencil operators
directly in DIA form: a d-dimensional grid of side ``n`` with a
``(2*radius+1)**d``-point stencil produces one diagonal per stencil tap at
offset ``sum_k tap_k * n**k``.

SPD guarantee: off-diagonal taps are ``-1``, the center tap is
``(#neighbors) + sigma`` with ``sigma > 0`` — a symmetrically diagonally
dominant matrix with positive diagonal, hence SPD (graph Laplacian + sigma*I
up to boundary truncation, which only strengthens dominance).
"""
from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np

from .formats import DIAMatrix

__all__ = ["poisson_dia", "poisson125", "poisson27", "poisson7", "stencil_offsets"]


def stencil_offsets(dim: int, n: int, radius: int) -> list[int]:
    """Linearized offsets of a dense (2r+1)^dim stencil on an n^dim grid."""
    offs = []
    for tap in itertools.product(range(-radius, radius + 1), repeat=dim):
        off = 0
        for k, t in enumerate(tap):
            off += t * n**k
        offs.append(off)
    return sorted(set(offs))


def poisson_dia(dim: int, n: int, radius: int, sigma: float = 1.0, dtype=jnp.float32) -> DIAMatrix:
    """SPD stencil operator on an ``n**dim`` grid in DIA storage.

    Boundary handling is Dirichlet truncation *in grid coordinates*: a tap
    is dropped when any coordinate leaves the grid (not merely the linear
    index — this avoids spurious wraparound couplings between grid rows).
    """
    N = n**dim
    taps = [t for t in itertools.product(range(-radius, radius + 1), repeat=dim) if any(t)]
    offsets = stencil_offsets(dim, n, radius)
    pos = {o: j for j, o in enumerate(offsets)}
    data = np.zeros((len(offsets), N), dtype=np.float64)

    # coordinates of every grid point, axis-major matching the offset formula
    idx = np.arange(N)
    coords = [(idx // n**k) % n for k in range(dim)]

    for tap in taps:
        off = sum(t * n**k for k, t in enumerate(tap))
        valid = np.ones(N, dtype=bool)
        for k, t in enumerate(tap):
            c = coords[k] + t
            valid &= (c >= 0) & (c < n)
        data[pos[off], valid] = -1.0

    # center: dominance over the actual (boundary-truncated) row sums
    center = -data.sum(axis=0) + sigma
    data[pos[0]] = center
    return DIAMatrix(jnp.asarray(data, dtype=dtype), tuple(offsets), N)


def poisson7(n: int, sigma: float = 1.0, dtype=jnp.float32) -> DIAMatrix:
    """3-D 7-point stencil (radius-1 star ~ classic Laplacian; we use the
    dense 27-pt box's star subset via radius=1 box minus corners is not
    needed for the paper — we keep the dense box generator and expose the
    7-pt as the 1-radius *star*)."""
    N = n**3
    offsets = sorted({0, 1, -1, n, -n, n * n, -(n * n)})
    pos = {o: j for j, o in enumerate(offsets)}
    data = np.zeros((len(offsets), N), dtype=np.float64)
    idx = np.arange(N)
    coords = [(idx // n**k) % n for k in range(3)]
    for k in range(3):
        for t in (-1, 1):
            off = t * n**k
            c = coords[k] + t
            valid = (c >= 0) & (c < n)
            data[pos[off], valid] = -1.0
    data[pos[0]] = -data.sum(axis=0) + sigma
    return DIAMatrix(jnp.asarray(data, dtype=dtype), tuple(offsets), N)


def poisson27(n: int, sigma: float = 1.0, dtype=jnp.float32) -> DIAMatrix:
    return poisson_dia(3, n, radius=1, sigma=sigma, dtype=dtype)


def poisson125(n: int, sigma: float = 1.0, dtype=jnp.float32) -> DIAMatrix:
    """The paper's 125-point (5x5x5) Poisson-class operator, nnz/N ~ 122."""
    return poisson_dia(3, n, radius=2, sigma=sigma, dtype=dtype)
