"""SPMV engine dispatch — one entry point, per-format/per-engine backends.

``spmv(A, x, engine=...)`` routes on (matrix type, engine) through a
registry instead of a hard-coded isinstance chain:

    format      engine="jnp"        other engines
    ---------   -----------------   ------------------------------------
    DIAMatrix   spmv_dia (shifts)   "pallas": kernels.spmv_dia (banded)
    BellMatrix  spmv_bell (gather)  "pallas": kernels.spmv_bell (B-ELL)
    CSRMatrix   spmv_csr (scatter)  "segsum": spmv_csr_segsum
    jax.Array   A @ x               — (falls back to jnp)
    any object with .matvec         — (protocol fallback, e.g. the
                                      matrix-free FunctionOperator)

``engine="auto"`` picks pallas on TPU and jnp elsewhere; an engine that is
not registered for the format falls back to jnp, so callers can request
"pallas" unconditionally. New formats/backends plug in via
``register_spmv`` without touching any solver code; re-registering an
existing (format, engine) pair raises unless ``overwrite=True``.

The jnp implementations double as the oracles the Pallas kernels are
validated against (tests/test_kernels.py, tests/test_sparse.py).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .formats import BellMatrix, CSRMatrix, DIAMatrix

__all__ = [
    "spmv",
    "spmv_dia",
    "spmv_bell",
    "spmv_csr",
    "spmv_csr_segsum",
    "shifted",
    "register_spmv",
    "spmv_engines",
]


def shifted(x: jax.Array, offset: int) -> jax.Array:
    """x shifted by a static offset with zero fill: out[i] = x[i+offset]."""
    n = x.shape[0]
    if offset == 0:
        return x
    if offset > 0:
        return jnp.concatenate([x[offset:], jnp.zeros((offset,), x.dtype)])
    return jnp.concatenate([jnp.zeros((-offset,), x.dtype), x[:offset]])


def spmv_dia(A: DIAMatrix, x: jax.Array) -> jax.Array:
    """y[i] = sum_j data[j, i] * x[i + offsets[j]] (zero outside [0, n))."""
    y = jnp.zeros_like(x)
    for j, o in enumerate(A.offsets):
        y = y + A.data[j] * shifted(x, o)
    return y


def spmv_bell(A: BellMatrix, x: jax.Array) -> jax.Array:
    gathered = x[A.cols]  # (n, R)
    return (A.vals * gathered).sum(axis=1)


def spmv_csr(A: CSRMatrix, x: jax.Array) -> jax.Array:
    """Reference CSR SPMV: gather columns, scatter-add into rows."""
    return jnp.zeros((A.n,), x.dtype).at[A.rows].add(A.vals * x[A.cols])


def spmv_csr_segsum(A: CSRMatrix, x: jax.Array) -> jax.Array:
    """CSR SPMV as a sorted segment-sum over per-entry products.

    ``rows`` is sorted by construction, so XLA lowers this to a single
    contiguous segmented reduction instead of generic scatter-adds.
    """
    return jax.ops.segment_sum(
        A.vals * x[A.cols], A.rows, num_segments=A.n, indices_are_sorted=True
    )


def _spmv_dense(A, x: jax.Array) -> jax.Array:
    return A @ x


def _spmv_dia_pallas(A: DIAMatrix, x: jax.Array) -> jax.Array:
    from ..kernels.spmv_dia import spmv_dia_pallas  # lazy: avoid import cycle

    return spmv_dia_pallas(A, x)


def _spmv_bell_pallas(A: BellMatrix, x: jax.Array) -> jax.Array:
    from ..kernels.spmv_bell import spmv_bell_pallas
    from ..kernels.spmv_bell.ops import _VMEM_ROWS_LIMIT

    if A.n > _VMEM_ROWS_LIMIT:  # kernel keeps x resident in VMEM
        return spmv_bell(A, x)
    return spmv_bell_pallas(A, x)


# (matrix type) -> (engine name) -> fn(A, x) -> y
_REGISTRY: Dict[type, Dict[str, Callable]] = {}


def register_spmv(mat_type: type, engine: str, fn: Callable, *, overwrite: bool = False) -> None:
    """Register an SPMV backend for ``mat_type`` under ``engine``.

    Raises ValueError if that (format, engine) pair is already registered,
    unless ``overwrite=True`` — silent replacement hides plug-in clashes.
    """
    table = _REGISTRY.setdefault(mat_type, {})
    if engine in table and not overwrite:
        raise ValueError(
            f"SPMV engine {engine!r} already registered for "
            f"{mat_type.__name__}; pass overwrite=True to replace it"
        )
    table[engine] = fn


register_spmv(DIAMatrix, "jnp", spmv_dia)
register_spmv(DIAMatrix, "pallas", _spmv_dia_pallas)
register_spmv(BellMatrix, "jnp", spmv_bell)
register_spmv(BellMatrix, "pallas", _spmv_bell_pallas)
register_spmv(CSRMatrix, "jnp", spmv_csr)
register_spmv(CSRMatrix, "segsum", spmv_csr_segsum)


def _spmv_matvec(A, x: jax.Array) -> jax.Array:
    return A.matvec(x)


def _engines_for(A) -> Dict[str, Callable]:
    # merge along the MRO: a subclass inherits its base format's engines
    # and may override/extend them
    table: Dict[str, Callable] = {}
    for klass in reversed(type(A).__mro__):
        table.update(_REGISTRY.get(klass, {}))
    if table:
        return table
    if hasattr(A, "matvec"):  # LinearOperator protocol (matrix-free etc.)
        return {"jnp": _spmv_matvec}
    if isinstance(A, jax.Array) or hasattr(A, "ndim"):
        return {"jnp": _spmv_dense}
    raise TypeError(f"unsupported matrix type {type(A)}")


def spmv_engines(A) -> Tuple[str, ...]:
    """Engine names available for this matrix (after fallback: always >=1)."""
    return tuple(sorted(_engines_for(A)))


def spmv(A, x: jax.Array, engine: str = "auto") -> jax.Array:
    """y = A @ x through the engine registry.

    engine="auto" — pallas on TPU (when registered), jnp elsewhere.
    An engine not registered for this format falls back to "jnp".
    """
    table = _engines_for(A)
    if engine == "auto":
        engine = "pallas" if jax.default_backend() == "tpu" and "pallas" in table else "jnp"
    fn = table.get(engine) or table.get("jnp")
    if fn is None:
        raise ValueError(f"no SPMV engine {engine!r} (or jnp fallback) for {type(A).__name__}")
    return fn(A, x)
