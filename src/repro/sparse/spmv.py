"""Reference (pure-jnp) SPMV implementations for every device format.

These are the oracles the Pallas kernels are validated against and the
fallback path on platforms without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .formats import BellMatrix, DIAMatrix

__all__ = ["spmv", "spmv_dia", "spmv_bell", "shifted"]


def shifted(x: jax.Array, offset: int) -> jax.Array:
    """x shifted by a static offset with zero fill: out[i] = x[i+offset]."""
    n = x.shape[0]
    if offset == 0:
        return x
    if offset > 0:
        return jnp.concatenate([x[offset:], jnp.zeros((offset,), x.dtype)])
    return jnp.concatenate([jnp.zeros((-offset,), x.dtype), x[:offset]])


def spmv_dia(A: DIAMatrix, x: jax.Array) -> jax.Array:
    """y[i] = sum_j data[j, i] * x[i + offsets[j]] (zero outside [0, n))."""
    y = jnp.zeros_like(x)
    for j, o in enumerate(A.offsets):
        y = y + A.data[j] * shifted(x, o)
    return y


def spmv_bell(A: BellMatrix, x: jax.Array) -> jax.Array:
    gathered = x[A.cols]  # (n, R)
    return (A.vals * gathered).sum(axis=1)


def spmv(A, x: jax.Array) -> jax.Array:
    if isinstance(A, DIAMatrix):
        return spmv_dia(A, x)
    if isinstance(A, BellMatrix):
        return spmv_bell(A, x)
    if isinstance(A, jax.Array) or hasattr(A, "ndim"):
        return A @ x
    raise TypeError(f"unsupported matrix type {type(A)}")
