"""SPMV engine dispatch — one entry point, per-format/per-engine backends.

``spmv(A, x, engine=...)`` routes on (matrix type, engine) through a
registry instead of a hard-coded isinstance chain. The full selection
matrix (engine x format):

    engine      DIAMatrix            BellMatrix          CSRMatrix
    ---------   ------------------   -----------------   ------------------
    "jnp"       spmv_dia (shifts)    spmv_bell (gather)  spmv_csr (scatter)
    "pallas"    kernels.spmv_dia     kernels.spmv_bell   — (jnp fallback)
                (banded, 3-window)   (B-ELL, VMEM x)
    "segsum"    — (jnp fallback)     — (jnp fallback)    spmv_csr_segsum
    "bf16"      spmv_dia_bf16        — (jnp fallback)    — (jnp fallback)
                (bf16 storage,
                 f32 accumulate)

    jax.Array            -> A @ x (dense "jnp" fallback)
    object with .matvec  -> protocol fallback (matrix-free FunctionOperator)

``engine="auto"`` resolution (see :func:`resolve_engine`): "pallas" on
TPU when registered for the format; otherwise the fastest registered
non-reference engine for this backend — today that is "segsum" for
``CSRMatrix`` on CPU/GPU (a sorted segmented reduction, much faster than
the scatter-add reference) — falling back to "jnp". An engine that is
not registered for the format falls back to jnp, so callers can request
"pallas" unconditionally. New formats/backends plug in via
``register_spmv`` without touching any solver code; re-registering an
existing (format, engine) pair raises unless ``overwrite=True``.

"bf16" is the mixed-precision engine the communication-reduced CG
variants lean on (arXiv 2501.03743): band data and x are stored/streamed
as bf16 (half the HBM traffic of f32) while products accumulate in f32.
It is meant to be paired with residual replacement — ``repro.plan``
turns ``replace_every`` on by default for plans that select it.

The jnp implementations double as the oracles the Pallas kernels are
validated against (tests/test_kernels.py, tests/test_sparse.py).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .formats import BellMatrix, CSRMatrix, DIAMatrix

__all__ = [
    "spmv",
    "spmv_dia",
    "spmv_dia_bf16",
    "spmv_bell",
    "spmv_csr",
    "spmv_csr_segsum",
    "shifted",
    "register_spmv",
    "resolve_engine",
    "spmv_engines",
]


def shifted(x: jax.Array, offset: int) -> jax.Array:
    """x shifted by a static offset with zero fill: out[i] = x[i+offset]."""
    n = x.shape[0]
    if offset == 0:
        return x
    if offset > 0:
        return jnp.concatenate([x[offset:], jnp.zeros((offset,), x.dtype)])
    return jnp.concatenate([jnp.zeros((-offset,), x.dtype), x[:offset]])


def spmv_dia(A: DIAMatrix, x: jax.Array) -> jax.Array:
    """y[i] = sum_j data[j, i] * x[i + offsets[j]] (zero outside [0, n))."""
    y = jnp.zeros_like(x)
    for j, o in enumerate(A.offsets):
        y = y + A.data[j] * shifted(x, o)
    return y


def spmv_bell(A: BellMatrix, x: jax.Array) -> jax.Array:
    gathered = x[A.cols]  # (n, R)
    return (A.vals * gathered).sum(axis=1)


def spmv_csr(A: CSRMatrix, x: jax.Array) -> jax.Array:
    """Reference CSR SPMV: gather columns, scatter-add into rows."""
    return jnp.zeros((A.n,), x.dtype).at[A.rows].add(A.vals * x[A.cols])


def spmv_csr_segsum(A: CSRMatrix, x: jax.Array) -> jax.Array:
    """CSR SPMV as a sorted segment-sum over per-entry products.

    ``rows`` is sorted by construction, so XLA lowers this to a single
    contiguous segmented reduction instead of generic scatter-adds.
    """
    return jax.ops.segment_sum(
        A.vals * x[A.cols], A.rows, num_segments=A.n, indices_are_sorted=True
    )


def spmv_dia_bf16(A: DIAMatrix, x: jax.Array) -> jax.Array:
    """Mixed-precision DIA SPMV: bf16 storage/streaming, f32 accumulation.

    Band data and x are cast to bf16 (halving the per-iteration HBM
    traffic of the memory-bound SPMV), every product accumulates in at
    least f32, and the result is returned in x's dtype. On TPU this runs
    the Pallas banded kernel on the bf16 operands (it accumulates f32
    internally); elsewhere the jnp shift form with explicit f32 upcasts.

    Expect O(1e-2) relative error per apply — pair with residual
    replacement (``replace_every``) for full-accuracy solves; plans
    default it on for this engine.
    """
    acc = jnp.promote_types(x.dtype, jnp.float32)
    data16 = A.data.astype(jnp.bfloat16)
    x16 = x.astype(jnp.bfloat16)
    if jax.default_backend() == "tpu":
        from ..kernels.spmv_dia import spmv_dia_pallas

        A16 = DIAMatrix(data16, A.offsets, A.n)
        return spmv_dia_pallas(A16, x16, out_dtype=acc).astype(x.dtype)
    y = jnp.zeros(x.shape, acc)
    for j, o in enumerate(A.offsets):
        y = y + data16[j].astype(acc) * shifted(x16, o).astype(acc)
    return y.astype(x.dtype)


def _spmv_dense(A, x: jax.Array) -> jax.Array:
    return A @ x


def _spmv_dia_pallas(A: DIAMatrix, x: jax.Array) -> jax.Array:
    from ..kernels.spmv_dia import spmv_dia_pallas  # lazy: avoid import cycle

    return spmv_dia_pallas(A, x)


def _spmv_bell_pallas(A: BellMatrix, x: jax.Array) -> jax.Array:
    from ..kernels.spmv_bell import spmv_bell_pallas
    from ..kernels.spmv_bell.ops import _VMEM_ROWS_LIMIT

    if A.n > _VMEM_ROWS_LIMIT:  # kernel keeps x resident in VMEM
        return spmv_bell(A, x)
    return spmv_bell_pallas(A, x)


# (matrix type) -> (engine name) -> fn(A, x) -> y
_REGISTRY: Dict[type, Dict[str, Callable]] = {}


def register_spmv(mat_type: type, engine: str, fn: Callable, *, overwrite: bool = False) -> None:
    """Register an SPMV backend for ``mat_type`` under ``engine``.

    Raises ValueError if that (format, engine) pair is already registered,
    unless ``overwrite=True`` — silent replacement hides plug-in clashes.
    """
    table = _REGISTRY.setdefault(mat_type, {})
    if engine in table and not overwrite:
        raise ValueError(
            f"SPMV engine {engine!r} already registered for "
            f"{mat_type.__name__}; pass overwrite=True to replace it"
        )
    table[engine] = fn


register_spmv(DIAMatrix, "jnp", spmv_dia)
register_spmv(DIAMatrix, "pallas", _spmv_dia_pallas)
register_spmv(DIAMatrix, "bf16", spmv_dia_bf16)
register_spmv(BellMatrix, "jnp", spmv_bell)
register_spmv(BellMatrix, "pallas", _spmv_bell_pallas)
register_spmv(CSRMatrix, "jnp", spmv_csr)
register_spmv(CSRMatrix, "segsum", spmv_csr_segsum)


def _spmv_matvec(A, x: jax.Array) -> jax.Array:
    return A.matvec(x)


def _engines_for(A) -> Dict[str, Callable]:
    # merge along the MRO: a subclass inherits its base format's engines
    # and may override/extend them
    table: Dict[str, Callable] = {}
    for klass in reversed(type(A).__mro__):
        table.update(_REGISTRY.get(klass, {}))
    if table:
        return table
    if hasattr(A, "matvec"):  # LinearOperator protocol (matrix-free etc.)
        return {"jnp": _spmv_matvec}
    if isinstance(A, jax.Array) or hasattr(A, "ndim"):
        return {"jnp": _spmv_dense}
    raise TypeError(f"unsupported matrix type {type(A)}")


def spmv_engines(A) -> Tuple[str, ...]:
    """Engine names available for this matrix (after fallback: always >=1)."""
    return tuple(sorted(_engines_for(A)))


def resolve_engine(A, engine: str = "auto") -> str:
    """The engine name ``spmv(A, x, engine=...)`` will actually run.

    "auto" resolution, in order:

    1. "pallas" on TPU when registered for this format;
    2. "segsum" when registered (CSRMatrix on CPU/GPU: the sorted
       segmented reduction beats the scatter-add reference everywhere);
    3. "jnp".

    A concrete engine name resolves to itself when registered, else to
    the "jnp" fallback.
    """
    table = _engines_for(A)
    if engine == "auto":
        if jax.default_backend() == "tpu" and "pallas" in table:
            return "pallas"
        if "segsum" in table:
            return "segsum"
        return "jnp"
    return engine if engine in table else "jnp"


def spmv(A, x: jax.Array, engine: str = "auto") -> jax.Array:
    """y = A @ x through the engine registry.

    engine="auto" — see :func:`resolve_engine` (pallas on TPU, segsum for
    CSR elsewhere, else jnp). An engine not registered for this format
    falls back to "jnp".
    """
    table = _engines_for(A)
    fn = table.get(resolve_engine(A, engine))
    if fn is None:
        raise ValueError(f"no SPMV engine {engine!r} (or jnp fallback) for {type(A).__name__}")
    return fn(A, x)
