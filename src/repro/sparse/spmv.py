"""SPMV engine dispatch — one entry point, per-format/per-engine backends.

``spmv(A, x, engine=...)`` routes on (matrix type, engine) through a
registry instead of a hard-coded isinstance chain:

    format      engine="jnp"        engine="pallas"
    ---------   -----------------   ------------------------------------
    DIAMatrix   spmv_dia (shifts)   kernels.spmv_dia (banded TPU kernel)
    BellMatrix  spmv_bell (gather)  kernels.spmv_bell (Block-ELLPACK)
    jax.Array   A @ x               — (falls back to jnp)

``engine="auto"`` picks pallas on TPU and jnp elsewhere; an engine that is
not registered for the format falls back to jnp, so callers can request
"pallas" unconditionally. New formats/backends plug in via
``register_spmv`` without touching any solver code.

The jnp implementations double as the oracles the Pallas kernels are
validated against (tests/test_kernels.py, tests/test_sparse.py).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .formats import BellMatrix, DIAMatrix

__all__ = [
    "spmv",
    "spmv_dia",
    "spmv_bell",
    "shifted",
    "register_spmv",
    "spmv_engines",
]


def shifted(x: jax.Array, offset: int) -> jax.Array:
    """x shifted by a static offset with zero fill: out[i] = x[i+offset]."""
    n = x.shape[0]
    if offset == 0:
        return x
    if offset > 0:
        return jnp.concatenate([x[offset:], jnp.zeros((offset,), x.dtype)])
    return jnp.concatenate([jnp.zeros((-offset,), x.dtype), x[:offset]])


def spmv_dia(A: DIAMatrix, x: jax.Array) -> jax.Array:
    """y[i] = sum_j data[j, i] * x[i + offsets[j]] (zero outside [0, n))."""
    y = jnp.zeros_like(x)
    for j, o in enumerate(A.offsets):
        y = y + A.data[j] * shifted(x, o)
    return y


def spmv_bell(A: BellMatrix, x: jax.Array) -> jax.Array:
    gathered = x[A.cols]  # (n, R)
    return (A.vals * gathered).sum(axis=1)


def _spmv_dense(A, x: jax.Array) -> jax.Array:
    return A @ x


def _spmv_dia_pallas(A: DIAMatrix, x: jax.Array) -> jax.Array:
    from ..kernels.spmv_dia import spmv_dia_pallas  # lazy: avoid import cycle

    return spmv_dia_pallas(A, x)


def _spmv_bell_pallas(A: BellMatrix, x: jax.Array) -> jax.Array:
    from ..kernels.spmv_bell import spmv_bell_pallas
    from ..kernels.spmv_bell.ops import _VMEM_ROWS_LIMIT

    if A.n > _VMEM_ROWS_LIMIT:  # kernel keeps x resident in VMEM
        return spmv_bell(A, x)
    return spmv_bell_pallas(A, x)


# (matrix type) -> (engine name) -> fn(A, x) -> y
_REGISTRY: Dict[type, Dict[str, Callable]] = {}


def register_spmv(mat_type: type, engine: str, fn: Callable) -> None:
    """Register an SPMV backend for ``mat_type`` under ``engine``."""
    _REGISTRY.setdefault(mat_type, {})[engine] = fn


register_spmv(DIAMatrix, "jnp", spmv_dia)
register_spmv(DIAMatrix, "pallas", _spmv_dia_pallas)
register_spmv(BellMatrix, "jnp", spmv_bell)
register_spmv(BellMatrix, "pallas", _spmv_bell_pallas)


def _engines_for(A) -> Dict[str, Callable]:
    # merge along the MRO: a subclass inherits its base format's engines
    # and may override/extend them
    table: Dict[str, Callable] = {}
    for klass in reversed(type(A).__mro__):
        table.update(_REGISTRY.get(klass, {}))
    if table:
        return table
    if isinstance(A, jax.Array) or hasattr(A, "ndim"):
        return {"jnp": _spmv_dense}
    raise TypeError(f"unsupported matrix type {type(A)}")


def spmv_engines(A) -> Tuple[str, ...]:
    """Engine names available for this matrix (after fallback: always >=1)."""
    return tuple(sorted(_engines_for(A)))


def spmv(A, x: jax.Array, engine: str = "auto") -> jax.Array:
    """y = A @ x through the engine registry.

    engine="auto" — pallas on TPU (when registered), jnp elsewhere.
    An engine not registered for this format falls back to "jnp".
    """
    table = _engines_for(A)
    if engine == "auto":
        engine = "pallas" if jax.default_backend() == "tpu" and "pallas" in table else "jnp"
    fn = table.get(engine) or table.get("jnp")
    if fn is None:
        raise ValueError(f"no SPMV engine {engine!r} (or jnp fallback) for {type(A).__name__}")
    return fn(A, x)
