"""Linear operators — what the solvers actually require of ``A``.

The CG family never inspects matrix entries; it only ever applies ``A`` to
a vector. That contract is the :class:`LinearOperator` protocol
(``shape`` / ``dtype`` / ``matvec``), and every non-distributed solver
method accepts anything satisfying it:

* the materialized formats — ``DIAMatrix`` / ``BellMatrix`` / ``CSRMatrix``
  (and dense ``jax.Array``) all carry ``matvec`` adapters routed through
  the ``sparse.spmv`` engine registry;
* :class:`FunctionOperator` — a matrix-free operator wrapping an arbitrary
  traceable callable: stencils applied on the fly, Jacobian-vector
  products (``jax.jvp``), composed/shifted operators. Pass ``diag`` when
  the Jacobi preconditioner should be available (a matrix-free operator
  cannot derive its own diagonal).

``as_operator`` adapts plain callables and arrays to the protocol; the
distributed methods still need banded structure (a ``DIAMatrix``) because
their halo exchange is derived from the band offsets.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

__all__ = ["LinearOperator", "FunctionOperator", "CountingOperator", "as_operator"]


@runtime_checkable
class LinearOperator(Protocol):
    """Structural contract every solver method accepts for ``A``."""

    @property
    def shape(self) -> Tuple[int, int]: ...

    @property
    def dtype(self) -> Any: ...

    def matvec(self, x: jax.Array) -> jax.Array: ...


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["diag"],
    meta_fields=["fn", "n", "out_dtype"],
)
@dataclass(frozen=True)
class FunctionOperator:
    """Matrix-free SPD operator: ``y = fn(x)`` with no materialized matrix.

    ``fn`` must be a jit-traceable ``(n,) -> (n,)`` map that is linear and
    symmetric positive definite (the solvers assume, not check, this).
    ``diag`` is the operator diagonal, required only when a Jacobi
    preconditioner is requested. Registered as a pytree: ``fn``/``n``/
    ``out_dtype`` are static metadata (a new ``fn`` object means a new jit
    trace — build the operator once and reuse it, e.g. via ``repro.plan``).
    """

    fn: Callable[[jax.Array], jax.Array]
    n: int
    out_dtype: Any = jnp.float32
    diag: Optional[jax.Array] = None

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.n)

    @property
    def dtype(self):
        return jnp.dtype(self.out_dtype)

    def matvec(self, x: jax.Array) -> jax.Array:
        return self.fn(x)

    def diagonal(self) -> jax.Array:
        if self.diag is None:
            raise ValueError(
                "matrix-free FunctionOperator has no diagonal; pass diag= at "
                "construction, or solve with M='identity' / an explicit "
                "preconditioner object"
            )
        return self.diag


class CountingOperator:
    """Matvec-counting wrapper: serve/benchmark accounting for operator cost.

    Wraps any :class:`LinearOperator` (or dense array / matrix container)
    and counts applications on the host:

        C = CountingOperator(A)
        p = repro.plan(C, method="pipecg", M="jacobi")
        res = p.solve(b)
        C.applications(res)        # matvecs this solve actually performed

    ``calls`` counts *invocations of* ``matvec`` — in eager code that is
    the number of operator applications; through a jitted solve each
    **call site** in the program counts once, at trace time, and never
    again on warm solves (``trace_calls`` isolates the traced ones — a
    PIPECG program shows 4: three setup matvecs plus the ONE loop-body
    site). ``applications(result)`` converts sites into per-solve
    operator applications: setup sites execute once, the loop site runs
    ``result.iterations`` times. Registered as a LEAFLESS pytree
    whose aux data is the wrapper itself: jit-traced solves call
    ``matvec`` on the original host object (counters survive tracing),
    the base operator's arrays are embedded as trace constants, and a new
    wrapper object means a new trace — accounting, not a serving path.
    """

    def __init__(self, base):
        self.base = base
        self.calls = 0                 # total matvec invocations (host)
        self.trace_calls = 0           # invocations made under a jax trace

    @property
    def shape(self) -> Tuple[int, int]:
        return self.base.shape

    @property
    def dtype(self):
        return getattr(self.base, "dtype", jnp.float32)

    def matvec(self, x: jax.Array) -> jax.Array:
        self.calls += 1
        if isinstance(x, jax.core.Tracer):
            self.trace_calls += 1
        from .spmv import spmv  # routes formats/dense/protocol alike

        return spmv(self.base, x)

    def diagonal(self) -> jax.Array:
        if not hasattr(self.base, "diagonal"):
            raise ValueError(
                f"{type(self.base).__name__} has no diagonal(); use "
                "M='identity' or an explicit preconditioner"
            )
        return self.base.diagonal()

    def reset(self) -> None:
        self.calls = 0
        self.trace_calls = 0

    def applications(self, result, loop_sites: int = 1) -> int:
        """Matvecs one solve through ONE traced program performed.

        Setup call sites (``trace_calls - loop_sites``) execute once per
        right-hand side; each loop site executes ``iterations`` times
        (``loop_sites=1`` is the CG family: one SPMV in the pinned loop).
        ``result`` is a ``SolveResult``; a batched result sums its per-rhs
        iteration counts and multiplies setup by the batch size. Only
        meaningful while a single program has been traced — ``reset()``
        between programs to attribute counts.
        """
        import numpy as np

        iters = np.asarray(result.iterations)
        k = max(iters.size, 1)
        setup = max(self.trace_calls - loop_sites, 0)
        return int(setup * k + loop_sites * int(iters.sum()))


jax.tree_util.register_pytree_node(
    CountingOperator,
    lambda op: ((), op),
    lambda op, _children: op,
)


def as_operator(A, n: int | None = None, dtype=None, diag=None):
    """Adapt ``A`` to the :class:`LinearOperator` protocol.

    Matrix containers and dense arrays pass through unchanged (the spmv
    registry already dispatches on them); a bare callable is wrapped into a
    :class:`FunctionOperator` (``n`` is then required).
    """
    if hasattr(A, "matvec") and hasattr(A, "shape"):
        return A
    if isinstance(A, jax.Array) or hasattr(A, "ndim"):
        return A
    if callable(A):
        if n is None:
            raise ValueError("as_operator(callable) needs n= (operator size)")
        return FunctionOperator(fn=A, n=n, out_dtype=dtype or jnp.float32, diag=diag)
    raise TypeError(f"cannot adapt {type(A).__name__} to a LinearOperator")
