"""Linear operators — what the solvers actually require of ``A``.

The CG family never inspects matrix entries; it only ever applies ``A`` to
a vector. That contract is the :class:`LinearOperator` protocol
(``shape`` / ``dtype`` / ``matvec``), and every non-distributed solver
method accepts anything satisfying it:

* the materialized formats — ``DIAMatrix`` / ``BellMatrix`` / ``CSRMatrix``
  (and dense ``jax.Array``) all carry ``matvec`` adapters routed through
  the ``sparse.spmv`` engine registry;
* :class:`FunctionOperator` — a matrix-free operator wrapping an arbitrary
  traceable callable: stencils applied on the fly, Jacobian-vector
  products (``jax.jvp``), composed/shifted operators. Pass ``diag`` when
  the Jacobi preconditioner should be available (a matrix-free operator
  cannot derive its own diagonal).

``as_operator`` adapts plain callables and arrays to the protocol; the
distributed methods still need banded structure (a ``DIAMatrix``) because
their halo exchange is derived from the band offsets.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

__all__ = ["LinearOperator", "FunctionOperator", "as_operator"]


@runtime_checkable
class LinearOperator(Protocol):
    """Structural contract every solver method accepts for ``A``."""

    @property
    def shape(self) -> Tuple[int, int]: ...

    @property
    def dtype(self) -> Any: ...

    def matvec(self, x: jax.Array) -> jax.Array: ...


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["diag"],
    meta_fields=["fn", "n", "out_dtype"],
)
@dataclass(frozen=True)
class FunctionOperator:
    """Matrix-free SPD operator: ``y = fn(x)`` with no materialized matrix.

    ``fn`` must be a jit-traceable ``(n,) -> (n,)`` map that is linear and
    symmetric positive definite (the solvers assume, not check, this).
    ``diag`` is the operator diagonal, required only when a Jacobi
    preconditioner is requested. Registered as a pytree: ``fn``/``n``/
    ``out_dtype`` are static metadata (a new ``fn`` object means a new jit
    trace — build the operator once and reuse it, e.g. via ``repro.plan``).
    """

    fn: Callable[[jax.Array], jax.Array]
    n: int
    out_dtype: Any = jnp.float32
    diag: Optional[jax.Array] = None

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.n)

    @property
    def dtype(self):
        return jnp.dtype(self.out_dtype)

    def matvec(self, x: jax.Array) -> jax.Array:
        return self.fn(x)

    def diagonal(self) -> jax.Array:
        if self.diag is None:
            raise ValueError(
                "matrix-free FunctionOperator has no diagonal; pass diag= at "
                "construction, or solve with M='identity' / an explicit "
                "preconditioner object"
            )
        return self.diag


def as_operator(A, n: int | None = None, dtype=None, diag=None):
    """Adapt ``A`` to the :class:`LinearOperator` protocol.

    Matrix containers and dense arrays pass through unchanged (the spmv
    registry already dispatches on them); a bare callable is wrapped into a
    :class:`FunctionOperator` (``n`` is then required).
    """
    if hasattr(A, "matvec") and hasattr(A, "shape"):
        return A
    if isinstance(A, jax.Array) or hasattr(A, "ndim"):
        return A
    if callable(A):
        if n is None:
            raise ValueError("as_operator(callable) needs n= (operator size)")
        return FunctionOperator(fn=A, n=n, out_dtype=dtype or jnp.float32, diag=diag)
    raise TypeError(f"cannot adapt {type(A).__name__} to a LinearOperator")
