"""Sharded checkpointing with reshard-on-restore (elastic).

Format: one ``.npz`` holding every leaf keyed by its tree path, plus a JSON
manifest (step, shapes, dtypes, key order). Writes are atomic
(tmp dir + rename) so a job killed mid-save never corrupts the latest
checkpoint — table stakes for 1000-node runs.

Restore takes target *shardings* (not the source mesh): leaves are
``jax.device_put`` onto whatever mesh the restarted job has — the elastic
shrink/grow path (save on 512 chips, restore on 256) is the same code.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "available_steps"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p).strip("[].'\"") for p in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir: str, step: int, state: Any) -> str:
    """Atomically write ``state`` under ckpt_dir/step_<step>."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves = _flatten_with_names(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}
    manifest = {
        "step": int(step),
        "keys": sorted(host),
        "shapes": {k: list(v.shape) for k, v in host.items()},
        "dtypes": {k: str(v.dtype) for k, v in host.items()},
    }
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template: Any, shardings: Any = None) -> Any:
    """Rebuild ``template``-structured state from disk.

    ``shardings`` (optional) mirrors the template tree with
    jax.sharding.Sharding leaves (or a single sharding applied to all):
    leaves are device_put accordingly — this is where elastic resharding
    happens. Without it, arrays land on the default device.
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    names = _flatten_with_names(template)
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    keys_in_order = list(names.keys())
    assert len(keys_in_order) == len(leaves_t)

    if shardings is not None and not isinstance(shardings, dict):
        flat_sh = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if len(flat_sh) == 1:
            flat_sh = flat_sh * len(leaves_t)
    else:
        flat_sh = [None] * len(leaves_t)

    out_leaves = []
    for key, tleaf, sh in zip(keys_in_order, leaves_t, flat_sh):
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r} (manifest step {manifest['step']})")
        arr = data[key]
        want = tuple(getattr(tleaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs template {want}")
        if sh is not None:
            out_leaves.append(jax.device_put(arr, sh))
        else:
            out_leaves.append(jax.numpy.asarray(arr, dtype=getattr(tleaf, "dtype", arr.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
