"""whisper-tiny — enc-dec audio backbone, conv frontend stubbed
[arXiv:2212.04356; unverified]. 4L d_model=384 6H (kv=6) d_ff=1536
vocab=51865. LayerNorm + GELU + biased MHA; encoder sees 1500 stub frames.
"""
from .base import ArchConfig, register


@register("whisper-tiny")
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,        # decoder layers
        n_enc_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        qkv_bias=True,
        norm="layernorm",
        enc_seq=1500,
        source="[arXiv:2212.04356; unverified]",
    )
