"""qwen3-8b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].
36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
"""
from .base import ArchConfig, register


@register("qwen3-8b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12288,
        vocab_size=151936,
        qk_norm=True,
        head_dim=128,
        rope_theta=1000000.0,
        source="[hf:Qwen/Qwen3-8B; hf]",
    )
