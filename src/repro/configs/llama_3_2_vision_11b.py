"""llama-3.2-vision-11b — cross-attn image layers, ViT frontend stubbed
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. 40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256; a gated cross-attention layer after
every 4 self layers (8 cross layers total: 32 self + 8 cross = 40L).
"""
from .base import ArchConfig, register


@register("llama-3.2-vision-11b")
def config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500000.0,
        cross_attn_every=5,  # groups of 4 self + 1 cross
        n_img_tokens=1601,
        source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
    )
