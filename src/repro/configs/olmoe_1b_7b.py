"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060; hf].
16L d_model=2048 16H (GQA kv=16) d_ff=1024 (per expert) vocab=50304.
"""
from .base import ArchConfig, register


@register("olmoe-1b-7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        n_experts=64,
        top_k=8,
        qk_norm=True,  # OLMoE uses QK-norm
        source="[arXiv:2409.02060; hf]",
    )
