"""Architecture + shape configuration system.

One ``ArchConfig`` dataclass covers the six model families; each assigned
architecture file instantiates it with the published numbers and registers
it under its public id (``--arch <id>`` in the launchers).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "register", "get_config", "list_configs", "reduced"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    # 0 = materialize full (Tq, Tk) scores; >0 = online-softmax over KV
    # chunks of this size (flash-attention-style, beyond-paper §Perf knob)
    attn_chunk: int = 0

    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25

    # ssm / hybrid
    ssm_state: int = 0        # mamba2 state dim per head
    ssm_heads: int = 0        # 0 -> n_heads
    proj_factor: float = 2.0  # inner dim = proj_factor * d_model
    chunk: int = 128          # chunked-scan block length
    slstm_every: int = 0      # xlstm: every k-th block is sLSTM
    attn_every: int = 0       # zamba2: shared attn block every k mamba blocks

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500  # precomputed audio frame positions (stub frontend)

    # vlm (llama-3.2-vision)
    cross_attn_every: int = 0  # a cross-attn layer after every k self layers
    n_img_tokens: int = 0      # stubbed patch embeddings per image

    dtype: str = "bfloat16"
    # long_500k applicability: quadratic-attention archs skip it (DESIGN.md)
    subquadratic: bool = False

    source: str = ""  # provenance note [source; verified-tier]

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def ssm_heads_(self) -> int:
        return self.ssm_heads or self.n_heads

    @property
    def d_inner(self) -> int:
        return int(self.proj_factor * self.d_model)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The assigned LM shape set (applies to every architecture).
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ArchConfig:
    # import the per-arch modules lazily so the registry is populated
    from . import _load_all  # noqa: F401

    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    from . import _load_all

    _load_all()
    return sorted(_REGISTRY)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Smoke-test scale: same family/topology, tiny dims.

    Keeps every structural feature (GQA ratio, MoE experts>top_k, slstm/attn
    cadence, cross-attn cadence) while shrinking width/depth/vocab.
    """
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv * max(1, cfg.n_heads // max(cfg.n_kv_heads, 1)), kv)
    heads = min(heads, 4)
    kv = min(kv, heads)
    small = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else (cfg.attn_every + 1)),
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=min(cfg.ssm_heads_, 4) if cfg.family in ("ssm", "hybrid") else 0,
        chunk=16,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq=32 if cfg.n_enc_layers else cfg.enc_seq,
        n_img_tokens=16 if cfg.n_img_tokens else 0,
        slstm_every=min(cfg.slstm_every, 2) if cfg.slstm_every else 0,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        dtype="float32",
    )
    if cfg.slstm_every:
        small["n_layers"] = 2 * small["slstm_every"]
    if cfg.attn_every:
        small["n_layers"] = 2 * small["attn_every"]
    if cfg.cross_attn_every:
        small["cross_attn_every"] = 2
        small["n_layers"] = 6
    small.update(overrides)
    return replace(cfg, **small)
