"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304. d_ff=0 means no separate
FFN: the mLSTM/sLSTM blocks carry their own up/down projections
(proj_factor 2). Block cadence 7 mLSTM : 1 sLSTM (the paper's xLSTM[7:1]).
"""
from .base import ArchConfig, register


@register("xlstm-1.3b")
def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        head_dim=512,
        ssm_heads=4,
        proj_factor=2.0,
        slstm_every=8,
        chunk=256,
        subquadratic=True,
        source="[arXiv:2405.04517; unverified]",
    )
