"""zamba2-2.7b — Mamba2 blocks + shared attention block
[arXiv:2411.15242; hf]. 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64. Shared transformer block applied after every 6
Mamba2 blocks (weight sharing; LoRA deltas omitted — DESIGN.md §9).
"""
from .base import ArchConfig, register


@register("zamba2-2.7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_heads=32,
        proj_factor=2.0,
        attn_every=6,
        chunk=256,
        subquadratic=True,
        source="[arXiv:2411.15242; hf]",
    )
