"""Architecture configs: one module per assigned architecture (+ shapes).

Use ``get_config("<arch-id>")`` / ``list_configs()`` / ``SHAPES``.
"""
from .base import SHAPES, ArchConfig, ShapeConfig, get_config, list_configs, reduced

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from . import (  # noqa: F401
        granite_moe_1b_a400m,
        internlm2_1_8b,
        llama_3_2_vision_11b,
        olmoe_1b_7b,
        qwen2_5_14b,
        qwen3_8b,
        stablelm_1_6b,
        whisper_tiny,
        xlstm_1_3b,
        zamba2_2_7b,
    )

    _LOADED = True


__all__ = [
    "ArchConfig",
    "SHAPES",
    "ShapeConfig",
    "get_config",
    "list_configs",
    "reduced",
]
