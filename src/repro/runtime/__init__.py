from .fault_tolerance import CheckpointManager, StragglerTracker, run_with_recovery

__all__ = ["CheckpointManager", "StragglerTracker", "run_with_recovery"]
