"""Fault-tolerance runtime: checkpoint manager + straggler loop + elastic.

Designed for the 1000+-node regime:

* ``CheckpointManager`` — periodic async saves (a background thread, so
  the step loop never blocks on disk — the paper's hide-the-copy move
  applied to checkpoints), retention window, crash-safe resume
  (restore-or-init), and resume-exactness thanks to the deterministic
  data pipeline keyed by step.
* ``run_with_recovery`` — supervised step loop: on a step failure
  (preemption, injected fault) it restores the newest checkpoint and
  replays from there.
* straggler mitigation — ``core.perfmodel.StragglerTracker``; for the
  solver it feeds re-decomposition weights (the paper's performance
  model), for training it flags hosts for the scheduler.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from ..ckpt.checkpoint import available_steps, latest_step, restore_checkpoint, save_checkpoint
from ..core.perfmodel import StragglerTracker  # re-export for runtime users

__all__ = ["CheckpointManager", "run_with_recovery", "StragglerTracker"]


@dataclass
class CheckpointManager:
    directory: str
    save_every: int = 100
    keep: int = 3
    async_save: bool = True
    _thread: Optional[threading.Thread] = field(default=None, repr=False)
    _error: Optional[BaseException] = field(default=None, repr=False)

    def maybe_save(self, step: int, state: Any, force: bool = False) -> bool:
        if not force and (self.save_every <= 0 or step % self.save_every != 0):
            return False
        self.wait()  # one in-flight save at a time
        state = jax.tree.map(lambda x: x, state)  # snapshot the pytree refs

        def work():
            try:
                save_checkpoint(self.directory, step, state)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        import shutil, os

        steps = available_steps(self.directory)
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    def restore_latest(self, template: Any, shardings: Any = None):
        """Returns (state, step) or (None, None) when no checkpoint exists."""
        self.wait()
        s = latest_step(self.directory)
        if s is None:
            return None, None
        return restore_checkpoint(self.directory, s, template, shardings), s


def run_with_recovery(
    step_fn: Callable[[Any, int], Any],
    init_state: Any,
    n_steps: int,
    manager: CheckpointManager,
    *,
    start_step: int = 0,
    max_restarts: int = 3,
    on_restore: Optional[Callable[[int], None]] = None,
):
    """Supervised loop: state = step_fn(state, step). On an exception the
    newest checkpoint is restored and the loop replays from its step —
    with the deterministic pipeline this is an exact resume."""
    state = init_state
    step = start_step
    restarts = 0
    while step < n_steps:
        try:
            state = step_fn(state, step)
            step += 1
            manager.maybe_save(step, state)
        except KeyboardInterrupt:
            raise
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            restored, s = manager.restore_latest(jax.eval_shape(lambda: state))
            if restored is None:
                state, step = init_state, start_step
            else:
                state, step = restored, s
            if on_restore is not None:
                on_restore(step)
    manager.maybe_save(step, state, force=True)
    manager.wait()
    return state, step
