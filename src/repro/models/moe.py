"""Mixture-of-Experts FFN: top-k routing with capacity-bounded dispatch.

Two execution paths:

* ``moe_ffn`` — GShard-style "dropping" dispatch in plain pjit ops.
  Baseline: GSPMD must reshard the (E, C, d) dispatch buffer between the
  token layout (batch over data) and the expert layout (E over model),
  which it does with gather fall-backs ("involuntary full
  rematerialization") — the collective storm visible in the 40-cell
  baseline (EXPERIMENTS.md §Roofline: olmoe/granite cells).

* ``moe_ffn_sharded`` — explicit ``shard_map`` dispatch (§Perf fix).
  Key observation: activations are REPLICATED over the model axis (only
  batch is sharded over data), so every (data, model) device already holds
  its tokens AND its expert shard. Dispatch/combine are then purely local
  per device, each device computes its local experts' contribution for its
  local tokens, and ONE ``psum`` over the model axis assembles the output —
  the same single-AR cost as a dense tensor-parallel FFN. Capacity is per
  (data shard x expert), so routing quality matches the baseline on
  uniformly-shuffled batches.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .common import ParamSpec, current_mesh, shard_hint

__all__ = ["moe_params", "moe_ffn", "moe_ffn_sharded", "moe_capacity"]


def moe_params(d: int, f: int, n_experts: int) -> dict:
    return {
        "router": ParamSpec((d, n_experts), ("embed", None)),
        "w_gate": ParamSpec((n_experts, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": ParamSpec((n_experts, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": ParamSpec((n_experts, f, d), ("experts", "expert_mlp", "embed")),
    }


def moe_capacity(n_tokens: int, top_k: int, n_experts: int, capacity_factor: float) -> int:
    c = int(capacity_factor * n_tokens * top_k / n_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_ffn(p: dict, x: jax.Array, top_k: int, capacity_factor: float = 1.25,
            norm_topk: bool = True) -> tuple[jax.Array, jax.Array]:
    """x (T, d) -> (y (T, d), aux_loss scalar).

    aux_loss is the standard load-balancing loss (mean over experts of
    frac_tokens * frac_prob * E).
    """
    T, d = x.shape
    E = p["router"].shape[-1] if isinstance(p["router"], jax.Array) else p["router"].shape[-1]
    logits = (x @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    if norm_topk:
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = moe_capacity(T, top_k, E, capacity_factor)
    A = T * top_k
    flat_e = expert_idx.reshape(A)  # assignment -> expert
    tok_of = jnp.arange(A) // top_k  # assignment -> token

    # rank each assignment within its expert (stable: earlier tokens first)
    order = jnp.argsort(flat_e, stable=True)  # (A,)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")  # (E,)
    pos_sorted = jnp.arange(A) - first[sorted_e]
    pos = jnp.zeros((A,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C

    # dispatch: (E, C, d) buffer; dropped assignments scatter out of bounds
    drop_pos = jnp.where(keep, pos, C)  # == C -> dropped by mode="drop"
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[flat_e, drop_pos].set(x[tok_of], mode="drop")
    buf = shard_hint(buf, ("experts", None, None))

    # expert computation (E-parallel)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_up"]
    )
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out_e = shard_hint(out_e, ("experts", None, None))

    # combine: gather each kept assignment's output, weight by its gate
    safe_pos = jnp.minimum(pos, C - 1)
    y_a = out_e[flat_e, safe_pos]  # (A, d)
    wts = gate_vals.reshape(A).astype(x.dtype) * keep.astype(x.dtype)
    y = (y_a * wts[:, None]).reshape(T, top_k, d).sum(axis=1)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    assign_onehot = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    f_e = assign_onehot.mean(axis=0)
    P_e = probs.mean(axis=0)
    aux = E * jnp.sum(f_e * P_e)
    return y, aux


def moe_ffn_sharded(p: dict, x3: jax.Array, top_k: int, capacity_factor: float = 1.25,
                    norm_topk: bool = True) -> tuple[jax.Array, jax.Array]:
    """Explicit shard_map MoE (see module docstring). x3 is (B, T, d).

    Per (data, model) device: route MY tokens, keep only assignments to MY
    expert shard, compute locally, then ONE psum over 'model' combines the
    per-expert-shard partial outputs. No dispatch buffer ever crosses the
    interconnect.
    """
    mesh = current_mesh()
    assert mesh is not None, "moe_ffn_sharded requires use_sharding_rules(..., mesh=...)"
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    E = p["router"].shape[-1]
    M = mesh.shape["model"]
    assert E % M == 0, (E, M)
    E_loc = E // M
    n_data = 1
    for a in batch_axes:
        n_data *= mesh.shape[a]

    def body(xb, router, wg, wu, wd):
        B_loc, T, d = xb.shape
        n_tok = B_loc * T
        xf = xb.reshape(n_tok, d)
        logits = (xf @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
        if norm_topk:
            gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

        C = moe_capacity(n_tok, top_k, E, capacity_factor)
        A = n_tok * top_k
        flat_e = expert_idx.reshape(A)
        tok_of = jnp.arange(A) // top_k
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        pos_sorted = jnp.arange(A) - first[sorted_e]
        pos = jnp.zeros((A,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
        keep = pos < C

        # keep only MY expert shard's assignments
        e0 = jax.lax.axis_index("model") * E_loc
        mine = (flat_e >= e0) & (flat_e < e0 + E_loc)
        local_e = jnp.clip(flat_e - e0, 0, E_loc - 1)
        drop_pos = jnp.where(keep & mine, pos, C)  # others dropped by mode="drop"
        buf = jnp.zeros((E_loc, C, d), xb.dtype)
        buf = buf.at[local_e, drop_pos].set(xf[tok_of], mode="drop")

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum("ecd,edf->ecf", buf, wu)
        out_e = jnp.einsum("ecf,efd->ecd", h, wd)

        safe_pos = jnp.minimum(pos, C - 1)
        y_a = out_e[local_e, safe_pos]
        wts = gate_vals.reshape(A).astype(xb.dtype) * (keep & mine).astype(xb.dtype)
        y = (y_a * wts[:, None]).reshape(n_tok, top_k, d).sum(axis=1)
        y = jax.lax.psum(y, "model")  # the ONLY cross-device traffic

        # aux is identical on every model shard (same tokens, same router):
        # reduce over the batch axes only (mean over data shards)
        assign_onehot = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
        aux = E * jnp.sum(assign_onehot.mean(0) * probs.mean(0))
        aux = jax.lax.psum(aux, batch_axes) / n_data
        return y.reshape(B_loc, T, d), aux

    spec_x = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None, None)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec_x, P(None, None), P("model", None, None), P("model", None, None), P("model", None, None)),
        out_specs=(spec_x, P()),
    )
    return fn(x3, p["router"], p["w_gate"], p["w_up"], p["w_down"])
