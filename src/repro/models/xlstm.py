"""xLSTM LM (xlstm-1.3b): mLSTM blocks with periodic sLSTM blocks.

mLSTM = matrix-memory LSTM: exponential-gated linear attention with a
normalizer — mapped onto the shared chunked GLA engine (models/gla.py),
sub-quadratic in sequence length (so ``long_500k`` runs for this arch).
sLSTM = scalar-memory LSTM with recurrent gate connections — inherently
sequential, computed with ``lax.scan`` over time (stabilized exponential
gating per the xLSTM paper).

Simplifications vs. the released model (recorded in DESIGN.md §9): the
short causal conv in the mLSTM q/k path is omitted; gates use
sigmoid/log-sigmoid stabilization rather than the exp-gate + max-tracker.
Block cadence follows cfg.slstm_every (1.3b ~= 7 mLSTM : 1 sLSTM).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ParamSpec, apply_norm, make_norm_params, shard_hint
from .gla import GLAState, gla_chunked, gla_init_state, gla_step
from .transformer import embed_params, embed_tokens, stack_specs, unembed

__all__ = [
    "xlstm_layout",
    "xlstm_forward",
    "xlstm_decode",
    "xlstm_init_state",
    "XLSTMState",
]


class XLSTMState(NamedTuple):
    mlstm: GLAState          # stacked (n_mlstm, B, H, dk, dv) states
    slstm_c: jax.Array       # (n_slstm, B, NH, dh)
    slstm_n: jax.Array
    slstm_h: jax.Array


def _mlstm_params(cfg: ArchConfig) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    nh = cfg.ssm_heads_
    return {
        "norm": make_norm_params(d, cfg.norm),
        "w_in": ParamSpec((d, 2 * din), ("embed", "mlp")),       # [x_m | z gate]
        "wq": ParamSpec((din, din), ("mlp", "heads_flat")),
        "wk": ParamSpec((din, din), ("mlp", "heads_flat")),
        "wv": ParamSpec((din, din), ("mlp", "heads_flat")),
        "w_ig": ParamSpec((din, nh), ("mlp", None), init="zeros"),
        "b_ig": ParamSpec((nh,), (None,), init="zeros"),
        "w_fg": ParamSpec((din, nh), ("mlp", None), init="zeros"),
        "b_fg": ParamSpec((nh,), (None,), init="ones", scale=4.0),  # decay ~ 1 at init
        "w_out": ParamSpec((din, d), ("mlp", "embed")),
    }


def _slstm_params(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    nh = cfg.ssm_heads_
    dh = d // nh
    return {
        "norm": make_norm_params(d, cfg.norm),
        "w_gates": ParamSpec((d, 4 * d), ("embed", "mlp")),        # z i f o inputs
        "r_gates": ParamSpec((nh, dh, 4 * dh), (None, None, None), scale=0.5),
        "b_gates": ParamSpec((4 * d,), ("mlp",), init="zeros"),
        "w_out": ParamSpec((d, d), ("embed", "embed")),
    }


def xlstm_layout(cfg: ArchConfig) -> dict:
    n_s = cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0
    n_m = cfg.n_layers - n_s
    return {
        **embed_params(cfg),
        "mlstm": stack_specs(_mlstm_params(cfg), n_m),
        "slstm": stack_specs(_slstm_params(cfg), max(n_s, 1)),
    }


def _mlstm_apply(lp, x, cfg: ArchConfig, state: GLAState | None, step: bool):
    """x (B,T,d) chunked, or (B,1,d) recurrent when step=True."""
    B, T, d = x.shape
    nh = cfg.ssm_heads_
    din = cfg.d_inner
    dk = din // nh
    h = apply_norm(x, lp["norm"], cfg.norm)
    hm, z = jnp.split(h @ lp["w_in"], 2, axis=-1)
    q = (hm @ lp["wq"]).reshape(B, T, nh, dk)
    k = (hm @ lp["wk"]).reshape(B, T, nh, dk) / jnp.sqrt(dk).astype(x.dtype)
    v = (hm @ lp["wv"]).reshape(B, T, nh, dk)
    b_in = jax.nn.sigmoid((hm @ lp["w_ig"] + lp["b_ig"]).astype(jnp.float32))      # (B,T,NH)
    log_a = jax.nn.log_sigmoid((hm @ lp["w_fg"] + lp["b_fg"]).astype(jnp.float32))
    if step:
        y, new_state = gla_step(
            q[:, 0], k[:, 0], v[:, 0], log_a[:, 0], b_in[:, 0], state, normalize=True
        )
        y = y[:, None]  # (B,1,NH,dk)
    else:
        y, new_state = gla_chunked(q, k, v, log_a, b_in, cfg.chunk, state=state, normalize=True)
    y = y.reshape(B, T, din) * jax.nn.silu(z)
    return x + y @ lp["w_out"], new_state


def _slstm_apply(lp, x, cfg: ArchConfig, state, step: bool):
    """Sequential scalar-memory LSTM. state = (c, n, h_prev) each (B,NH,dh)."""
    B, T, d = x.shape
    nh = cfg.ssm_heads_
    dh = d // nh
    xin = apply_norm(x, lp["norm"], cfg.norm)
    gates_in = (xin @ lp["w_gates"] + lp["b_gates"]).reshape(B, T, nh, 4 * dh)

    def cell(carry, g_t):
        c, n, h_prev = carry  # (B,NH,dh) f32
        rec = jnp.einsum("bhd,hdg->bhg", h_prev, lp["r_gates"].astype(jnp.float32))
        g = g_t.astype(jnp.float32) + rec
        zr, ir, fr, orr = jnp.split(g, 4, axis=-1)
        z = jnp.tanh(zr)
        o = jax.nn.sigmoid(orr)
        log_f = jax.nn.log_sigmoid(fr)
        i = jnp.exp(jnp.minimum(ir, 10.0))
        f = jnp.exp(log_f)
        c_new = f * c + i * z
        n_new = f * n + i
        h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, h_new), h_new

    if step:
        (c, n, h), y = cell(state, gates_in[:, 0])
        y = y[:, None]
        new_state = (c, n, h)
    else:
        zero = jnp.zeros((B, nh, dh), jnp.float32)
        init = state if state is not None else (zero, zero, zero)
        new_state, ys = jax.lax.scan(cell, init, jnp.moveaxis(gates_in, 1, 0))
        y = jnp.moveaxis(ys, 0, 1)  # (B,T,NH,dh)
    y = y.reshape(B, T, d).astype(x.dtype)
    return x + y @ lp["w_out"], new_state


def _split_layers(cfg: ArchConfig):
    """Group pattern: (slstm_every - 1) mLSTM blocks then 1 sLSTM block."""
    k = cfg.slstm_every
    n_groups = cfg.n_layers // k
    return n_groups, k - 1


def xlstm_forward(params: dict, tokens: jax.Array, cfg: ArchConfig, *, remat: bool = False,
                  state: XLSTMState | None = None, return_state: bool = False):
    x = embed_tokens(params, tokens, cfg)
    n_groups, m_per = _split_layers(cfg)

    def m_tree(g):  # mLSTM specs for group g, reshaped (n_groups, m_per, ...)
        return jax.tree.map(lambda a: a.reshape(n_groups, m_per, *a.shape[1:])[g], params["mlstm"])

    states_m = []
    states_s = []
    for g in range(n_groups):
        def m_body(x, lp):
            y, st = _mlstm_apply(lp, x, cfg, None, step=False)
            return y, st

        from .transformer import remat_wrap

        fn = remat_wrap(m_body, remat)
        x, st_m = jax.lax.scan(fn, x, m_tree(g))
        s_lp = jax.tree.map(lambda a: a[g], params["slstm"])
        x, st_s = _slstm_apply(s_lp, x, cfg, None, step=False)
        states_m.append(st_m)
        states_s.append(st_s)

    logits = unembed(params, x, cfg)
    if return_state:
        # scan stacks per-layer states: each st_m.S is (m_per, B, NH, dk, dk)
        mS = GLAState(
            S=jnp.concatenate([st.S for st in states_m], axis=0),
            n=jnp.concatenate([st.n for st in states_m], axis=0),
        )
        return logits, XLSTMState(
            mlstm=mS,
            slstm_c=jnp.stack([s[0] for s in states_s]),
            slstm_n=jnp.stack([s[1] for s in states_s]),
            slstm_h=jnp.stack([s[2] for s in states_s]),
        )
    return logits


def xlstm_init_state(cfg: ArchConfig, batch: int) -> XLSTMState:
    nh = cfg.ssm_heads_
    din = cfg.d_inner
    dk = din // nh
    dh = cfg.d_model // nh
    n_groups, m_per = _split_layers(cfg)
    n_m = n_groups * m_per
    return XLSTMState(
        mlstm=GLAState(
            S=jnp.zeros((n_m, batch, nh, dk, dk), jnp.float32),
            n=jnp.zeros((n_m, batch, nh, dk), jnp.float32),
        ),
        slstm_c=jnp.zeros((n_groups, batch, nh, dh), jnp.float32),
        slstm_n=jnp.zeros((n_groups, batch, nh, dh), jnp.float32),
        slstm_h=jnp.zeros((n_groups, batch, nh, dh), jnp.float32),
    )


def xlstm_decode(params: dict, token: jax.Array, state: XLSTMState, pos, cfg: ArchConfig):
    """One token. SSM decode is O(1) in context length (no KV cache)."""
    x = embed_tokens(params, token, cfg)
    n_groups, m_per = _split_layers(cfg)

    new_mS, new_mN = [], []
    new_c, new_n, new_h = [], [], []
    for g in range(n_groups):
        for j in range(m_per):
            li = g * m_per + j
            lp = jax.tree.map(lambda a: a.reshape(n_groups, m_per, *a.shape[1:])[g, j], params["mlstm"])
            st = GLAState(S=state.mlstm.S[li], n=state.mlstm.n[li])
            x, st2 = _mlstm_apply(lp, x, cfg, st, step=True)
            new_mS.append(st2.S)
            new_mN.append(st2.n)
        s_lp = jax.tree.map(lambda a: a[g], params["slstm"])
        st_s = (state.slstm_c[g], state.slstm_n[g], state.slstm_h[g])
        x, (c, n, h) = _slstm_apply(s_lp, x, cfg, st_s, step=True)
        new_c.append(c)
        new_n.append(n)
        new_h.append(h)

    logits = unembed(params, x, cfg)
    del pos
    new_state = XLSTMState(
        mlstm=GLAState(S=jnp.stack(new_mS), n=jnp.stack(new_mN)),
        slstm_c=jnp.stack(new_c),
        slstm_n=jnp.stack(new_n),
        slstm_h=jnp.stack(new_h),
    )
    return logits, new_state
