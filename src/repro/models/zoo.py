"""Unified model API over the six families.

Every architecture exposes the same surface:

    api = build_model(cfg)
    params = api.init_params(key)          # or api.abstract_params()
    logits = api.forward(params, batch)    # train / prefill math
    logits, cache = api.prefill(params, batch, max_seq)
    cache = api.init_cache(batch_size, max_seq)
    logits, cache = api.decode(params, token, cache, pos)
    batch = api.input_specs(shape)         # ShapeDtypeStructs for dry-run

``batch`` is a dict with "tokens" (B, T) plus family extras:
encdec -> "frames" (stub audio frontend), vlm -> "img_feats" (stub ViT).
MoE forward returns (logits, aux); others return logits (aux=0 handled in
train/loss).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from .attention import KVCache
from .common import DTYPES, abstract, logical_axes_tree, materialize
from . import encdec as _encdec
from . import mamba as _mamba
from . import moe_lm as _moe
from . import transformer as _dense
from . import vlm as _vlm
from . import xlstm as _xlstm

__all__ = ["ModelApi", "build_model"]


@dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    layout: Dict[str, Any]
    forward: Callable  # (params, batch, remat=False) -> logits | (logits, aux)
    prefill: Callable  # (params, batch) -> (logits, cache)
    decode: Callable   # (params, token, cache, pos) -> (logits, cache)
    init_cache: Callable  # (batch_size, max_seq) -> cache pytree

    @property
    def dtype(self):
        return DTYPES[self.cfg.dtype]

    def init_params(self, key: jax.Array):
        return materialize(key, self.layout, self.dtype)

    def abstract_params(self):
        return abstract(self.layout, self.dtype)

    def param_logical_axes(self):
        return logical_axes_tree(self.layout)

    def n_params(self) -> int:
        import numpy as np

        return int(
            sum(np.prod(s.shape) for s in jax.tree.leaves(
                self.layout, is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "shape")))
        )

    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        B, T = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, T), i32),
            }
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, T), i32)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), self.dtype)
            if cfg.family == "vlm":
                specs["img_feats"] = jax.ShapeDtypeStruct((B, cfg.n_img_tokens, cfg.d_model), self.dtype)
            return specs
        # decode: one new token against a seq_len-deep cache/state
        cache = jax.eval_shape(lambda: self.init_cache(B, T))
        return {
            "token": jax.ShapeDtypeStruct((B, 1), i32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), i32),
        }


def _batch_extras(cfg: ArchConfig, batch: dict) -> tuple:
    if cfg.family == "encdec":
        return (batch["frames"],)
    if cfg.family == "vlm":
        return (batch["img_feats"],)
    return ()


def build_model(cfg: ArchConfig) -> ModelApi:
    fam = cfg.family
    dtype = DTYPES[cfg.dtype]

    if fam in ("dense",):
        layout = _dense.dense_lm_layout(cfg)

        def forward(params, batch, remat=False):
            return _dense.dense_lm_forward(params, batch["tokens"], cfg, remat=remat)

        def prefill(params, batch):
            logits, kvs = _dense.dense_lm_forward(params, batch["tokens"], cfg, return_cache=True)
            return logits, KVCache(*kvs)

        def init_cache(batch_size, max_seq):
            from .attention import init_kv_cache

            return init_kv_cache(cfg, batch_size, max_seq, cfg.n_layers, dtype)

        def decode(params, token, cache, pos):
            return _dense.dense_lm_decode(params, token, cache, pos, cfg)

    elif fam == "moe":
        layout = _moe.moe_lm_layout(cfg)

        def forward(params, batch, remat=False):
            return _moe.moe_lm_forward(params, batch["tokens"], cfg, remat=remat)

        def prefill(params, batch):
            logits, _aux, kvs = _moe.moe_lm_forward(params, batch["tokens"], cfg, return_cache=True)
            return logits, KVCache(*kvs)

        def init_cache(batch_size, max_seq):
            from .attention import init_kv_cache

            return init_kv_cache(cfg, batch_size, max_seq, cfg.n_layers, dtype)

        def decode(params, token, cache, pos):
            return _moe.moe_lm_decode(params, token, cache, pos, cfg)

    elif fam == "ssm":
        layout = _xlstm.xlstm_layout(cfg)

        def forward(params, batch, remat=False):
            return _xlstm.xlstm_forward(params, batch["tokens"], cfg, remat=remat)

        def prefill(params, batch):
            return _xlstm.xlstm_forward(params, batch["tokens"], cfg, return_state=True)

        def init_cache(batch_size, max_seq):
            del max_seq  # recurrent state: O(1) in context length
            return _xlstm.xlstm_init_state(cfg, batch_size)

        def decode(params, token, cache, pos):
            return _xlstm.xlstm_decode(params, token, cache, pos, cfg)

    elif fam == "hybrid":
        layout = _mamba.zamba_layout(cfg)

        def forward(params, batch, remat=False):
            return _mamba.zamba_forward(params, batch["tokens"], cfg, remat=remat)

        def prefill(params, batch):
            return _mamba.zamba_forward(params, batch["tokens"], cfg, return_state=True)

        def init_cache(batch_size, max_seq):
            return _mamba.zamba_init_state(cfg, batch_size, max_seq, dtype)

        def decode(params, token, cache, pos):
            return _mamba.zamba_decode(params, token, cache, pos, cfg)

    elif fam == "encdec":
        layout = _encdec.encdec_layout(cfg)

        def forward(params, batch, remat=False):
            return _encdec.encdec_forward(params, batch["tokens"], batch["frames"], cfg, remat=remat)

        def prefill(params, batch):
            logits, (kvs, enc_out) = _encdec.encdec_forward(
                params, batch["tokens"], batch["frames"], cfg, return_cache=True
            )
            return logits, _encdec.EncDecCache(self_kv=KVCache(*kvs), enc_out=enc_out)

        def init_cache(batch_size, max_seq):
            return _encdec.encdec_init_cache(cfg, batch_size, max_seq, dtype)

        def decode(params, token, cache, pos):
            return _encdec.encdec_decode(params, token, cache, pos, cfg)

    elif fam == "vlm":
        layout = _vlm.vlm_layout(cfg)

        def forward(params, batch, remat=False):
            return _vlm.vlm_forward(params, batch["tokens"], batch["img_feats"], cfg, remat=remat)

        def prefill(params, batch):
            logits, kv = _vlm.vlm_forward(
                params, batch["tokens"], batch["img_feats"], cfg, return_cache=True
            )
            return logits, _vlm.VLMCache(self_kv=kv, img_feats=batch["img_feats"])

        def init_cache(batch_size, max_seq):
            return _vlm.vlm_init_cache(cfg, batch_size, max_seq, dtype)

        def decode(params, token, cache, pos):
            return _vlm.vlm_decode(params, token, cache, pos, cfg)

    else:
        raise ValueError(f"unknown family {fam!r}")

    return ModelApi(
        cfg=cfg, layout=layout, forward=forward, prefill=prefill, decode=decode, init_cache=init_cache
    )
