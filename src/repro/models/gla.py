"""Chunked gated linear attention — the shared sub-quadratic engine.

Both mLSTM (xLSTM) and Mamba-2's SSD layer are scalar-decay linear
attention in disguise:

    S_t = a_t * S_{t-1} + b_t * k_t v_t^T          (state (dk, dv) per head)
    n_t = a_t * n_{t-1} + b_t * k_t                (normalizer, optional)
    y_t = q_t @ S_t [ / max(|q_t @ n_t|, 1) ]

with per-(head, step) scalars a_t (decay, in (0,1]) and b_t (input gate).
The chunkwise-parallel form (SSD / GLA style) computes within-chunk
interactions as a masked quadratic in the chunk (MXU-friendly (L, L)
matmuls) and carries the state across chunks with a ``lax.scan`` —
O(T * L) work instead of O(T^2), which is what makes ``long_500k``
runnable for the ssm/hybrid architectures.

Shapes: q, k (B, T, H, dk); v (B, T, H, dv); log_a, b (B, T, H).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["GLAState", "gla_init_state", "gla_chunked", "gla_step"]


class GLAState(NamedTuple):
    S: jax.Array  # (B, H, dk, dv)
    n: jax.Array  # (B, H, dk)


def gla_init_state(batch: int, heads: int, dk: int, dv: int, dtype=jnp.float32) -> GLAState:
    return GLAState(
        S=jnp.zeros((batch, heads, dk, dv), dtype),
        n=jnp.zeros((batch, heads, dk), dtype),
    )


def gla_chunked(q, k, v, log_a, b, chunk: int, *, state: GLAState | None = None, normalize: bool = False):
    """Full-sequence chunkwise pass. Returns (y (B,T,H,dv), final GLAState).

    T must be a multiple of ``chunk`` (pad upstream).
    """
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    L = chunk
    assert T % L == 0, (T, L)
    C = T // L
    f32 = jnp.float32

    # fold the input gate into k (k_t' = b_t * k_t)
    kb = k.astype(f32) * b.astype(f32)[..., None]

    def to_chunks(x):  # (B, T, ...) -> (C, B, L, ...)
        return jnp.moveaxis(x.reshape(B, C, L, *x.shape[2:]), 1, 0)

    qc = to_chunks(q.astype(f32))
    kc = to_chunks(kb)
    vc = to_chunks(v.astype(f32))
    ac = to_chunks(log_a.astype(f32))  # (C, B, L, H)

    if state is None:
        state = gla_init_state(B, H, dk, dv)

    def scan_fn(carry, inp):
        S, n = carry  # (B,H,dk,dv), (B,H,dk)
        qq, kk, vv, la = inp  # (B,L,H,dk), (B,L,H,dk), (B,L,H,dv), (B,L,H)
        # cumulative decay within the chunk: A_t = sum_{j<=t} log a_j
        A = jnp.cumsum(la, axis=1)  # (B,L,H)
        eA = jnp.exp(A)
        # inter-chunk: y_inter[t] = e^{A_t} q_t S_prev
        q_sc = qq * eA[..., None]
        y_inter = jnp.einsum("blhk,bhkv->blhv", q_sc, S)
        n_inter = jnp.einsum("blhk,bhk->blh", q_sc, n)
        # intra-chunk: D[t,s] = e^{A_t - A_s} for s <= t
        D = A[:, :, None, :] - A[:, None, :, :]  # (B, L_t, L_s, H)
        mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, :, :, None]
        D = jnp.where(mask, jnp.exp(D), 0.0)
        scores = jnp.einsum("blhk,bmhk->blmh", qq, kk) * D
        y_intra = jnp.einsum("blmh,bmhv->blhv", scores, vv)
        n_intra = jnp.einsum("blmh,bmhk->blhk", scores, jnp.ones_like(kk[..., :1])).squeeze(-1)
        # state update: S_new = e^{A_L} S + sum_s e^{A_L - A_s} k_s v_s^T
        eTot = jnp.exp(A[:, -1, :])  # (B,H)
        w = jnp.exp(A[:, -1:, :] - A)  # (B,L,H)
        k_sc = kk * w[..., None]
        S_new = S * eTot[..., None, None] + jnp.einsum("blhk,blhv->bhkv", k_sc, vv)
        n_new = n * eTot[..., None] + jnp.sum(k_sc, axis=1)
        return (S_new, n_new), (y_inter + y_intra, n_inter + n_intra)

    (S_f, n_f), (ys, ns) = jax.lax.scan(scan_fn, (state.S.astype(f32), state.n.astype(f32)), (qc, kc, vc, ac))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, dv)
    if normalize:
        den = jnp.moveaxis(ns, 0, 1).reshape(B, T, H)
        y = y / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return y.astype(v.dtype), GLAState(S=S_f, n=n_f)


def gla_step(q, k, v, log_a, b, state: GLAState, *, normalize: bool = False):
    """Single-token recurrent update. q,k (B,H,dk); v (B,H,dv); log_a,b (B,H)."""
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[..., None]  # (B,H,1)
    kb = k.astype(f32) * b.astype(f32)[..., None]
    S = state.S * a[..., None] + kb[..., :, None] * v.astype(f32)[..., None, :]
    n = state.n * a + kb
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(f32), S)
    if normalize:
        den = jnp.einsum("bhk,bhk->bh", q.astype(f32), n)
        y = y / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return y.astype(v.dtype), GLAState(S=S, n=n)
