"""Dense MLP blocks (SwiGLU / GELU) with tensor-parallel-friendly layouts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec, shard_hint

__all__ = ["swiglu_params", "swiglu", "gelu_mlp_params", "gelu_mlp"]


def swiglu_params(d: int, f: int) -> dict:
    return {
        "w_gate": ParamSpec((d, f), ("embed", "mlp")),
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard_hint(h, ("batch", None, "mlp"))
    return h @ p["w_down"]


def gelu_mlp_params(d: int, f: int) -> dict:
    return {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "b_up": ParamSpec((f,), ("mlp",), init="zeros"),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
        "b_down": ParamSpec((d,), ("embed",), init="zeros"),
    }


def gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    h = shard_hint(h, ("batch", None, "mlp"))
    return h @ p["w_down"] + p["b_down"]
