"""Llama-3.2-Vision-style VLM backbone: a dense decoder with gated
cross-attention layers interleaved every ``cross_attn_every`` self layers.

The ViT frontend is a STUB per the assignment: ``input_specs`` provides
pre-projected patch embeddings (B, n_img_tokens, d_model). Cross layers use
the zero-init tanh gate of the released model so initial behaviour matches
the text-only backbone.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import KVCache, attention, cross_attn_params, cross_attention
from .common import apply_norm, make_norm_params
from .mlp import swiglu, swiglu_params
from .transformer import (
    dense_layer_apply,
    dense_layer_params,
    embed_params,
    embed_tokens,
    stack_specs,
    unembed,
)

__all__ = ["vlm_layout", "vlm_forward", "vlm_decode", "VLMCache", "vlm_init_cache"]


class VLMCache(NamedTuple):
    self_kv: KVCache     # (L_self, B, S, KV, hd)
    img_feats: jax.Array  # (B, n_img, d)


def _cross_layer_params(cfg: ArchConfig) -> dict:
    return {
        "norm": make_norm_params(cfg.d_model, cfg.norm),
        "cross": cross_attn_params(cfg),
        "mlp_norm": make_norm_params(cfg.d_model, cfg.norm),
        "mlp": swiglu_params(cfg.d_model, cfg.d_ff),
        }


def _groups(cfg: ArchConfig) -> tuple[int, int]:
    """(n_groups, self_per_group): every group = k self layers + 1 cross."""
    k = cfg.cross_attn_every
    n_groups = cfg.n_layers // k
    return n_groups, k - 1


def vlm_layout(cfg: ArchConfig) -> dict:
    n_groups, self_per = _groups(cfg)
    return {
        **embed_params(cfg),
        "self_layers": stack_specs(dense_layer_params(cfg), n_groups * self_per),
        "cross_layers": stack_specs(_cross_layer_params(cfg), n_groups),
    }


def _cross_apply(lp, x, img, cfg: ArchConfig):
    h = apply_norm(x, lp["norm"], cfg.norm)
    x = x + cross_attention(lp["cross"], h, img, cfg, gated=True)
    h = apply_norm(x, lp["mlp_norm"], cfg.norm)
    return x + swiglu(lp["mlp"], h)


def vlm_forward(params: dict, tokens: jax.Array, img_feats: jax.Array, cfg: ArchConfig,
                *, remat: bool = False, return_cache: bool = False):
    x = embed_tokens(params, tokens, cfg)
    n_groups, self_per = _groups(cfg)

    def s_tree(g):
        return jax.tree.map(
            lambda a: a.reshape(n_groups, self_per, *a.shape[1:])[g], params["self_layers"]
        )

    kvs = []
    for g in range(n_groups):
        def body(x, lp):
            y, kv = dense_layer_apply(lp, x, cfg)
            return y, kv if return_cache else None

        from .transformer import remat_wrap

        fn = remat_wrap(body, remat)
        x, kv = jax.lax.scan(fn, x, s_tree(g))
        c_lp = jax.tree.map(lambda a: a[g], params["cross_layers"])
        x = _cross_apply(c_lp, x, img_feats, cfg)
        kvs.append(kv)

    logits = unembed(params, x, cfg)
    if return_cache:
        cache = KVCache(
            k=jnp.concatenate([kv[0] for kv in kvs], axis=0),
            v=jnp.concatenate([kv[1] for kv in kvs], axis=0),
        )
        return logits, cache
    return logits


def vlm_init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> VLMCache:
    n_groups, self_per = _groups(cfg)
    hd = cfg.head_dim_
    L = n_groups * self_per
    return VLMCache(
        self_kv=KVCache(
            k=jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            v=jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
        ),
        img_feats=jnp.zeros((batch, cfg.n_img_tokens, cfg.d_model), dtype),
    )


def vlm_decode(params: dict, token: jax.Array, cache: VLMCache, pos, cfg: ArchConfig):
    x = embed_tokens(params, token, cfg)
    n_groups, self_per = _groups(cfg)

    new_k, new_v = [], []
    for g in range(n_groups):
        for j in range(self_per):
            li = g * self_per + j
            lp = jax.tree.map(lambda a: a[li], params["self_layers"])
            kvc = KVCache(k=cache.self_kv.k[li], v=cache.self_kv.v[li])
            x, (kc, vc) = dense_layer_apply(lp, x, cfg, cache=kvc, cache_pos=pos)
            new_k.append(kc)
            new_v.append(vc)
        c_lp = jax.tree.map(lambda a: a[g], params["cross_layers"])
        x = _cross_apply(c_lp, x, cache.img_feats, cfg)

    logits = unembed(params, x, cfg)
    from .transformer import write_cache

    return logits, VLMCache(
        self_kv=write_cache(cache.self_kv, jnp.stack(new_k), jnp.stack(new_v), pos),
        img_feats=cache.img_feats,
    )
