"""Dense decoder-only LM (qwen2.5 / qwen3 / stablelm / internlm2) and the
building blocks reused by the MoE / VLM / hybrid families.

Layers are scanned (stacked weights with a leading ``layers`` axis): one
compiled layer body regardless of depth, which keeps 48-layer x 512-device
dry-runs tractable and makes remat policies uniform.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import KVCache, attention, attn_params
from .common import DTYPES, ParamSpec, apply_norm, make_norm_params, shard_hint
from .mlp import swiglu, swiglu_params

__all__ = [
    "stack_specs",
    "embed_params",
    "dense_layer_params",
    "dense_layer_apply",
    "dense_lm_layout",
    "dense_lm_forward",
    "dense_lm_decode",
    "embed_tokens",
    "unembed",
]


def stack_specs(tree, n: int):
    """Add a leading stacked-layers axis to every ParamSpec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.axes), init=s.init, scale=s.scale),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def embed_params(cfg: ArchConfig) -> dict:
    p = {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    p["final_norm"] = make_norm_params(cfg.d_model, cfg.norm)
    return p


def embed_tokens(params: dict, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = params["embedding"][tokens]
    return shard_hint(x, ("batch", None, None))


def unembed(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = apply_norm(x, params["final_norm"], cfg.norm)
    head = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return shard_hint(logits, ("batch", None, "vocab"))


def dense_layer_params(cfg: ArchConfig) -> dict:
    return {
        "attn_norm": make_norm_params(cfg.d_model, cfg.norm),
        "attn": attn_params(cfg),
        "mlp_norm": make_norm_params(cfg.d_model, cfg.norm),
        "mlp": swiglu_params(cfg.d_model, cfg.d_ff),
    }


def dense_layer_apply(
    lp: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions=None,
    cache: Optional[KVCache] = None,
    cache_pos=None,
):
    from jax.ad_checkpoint import checkpoint_name

    h = apply_norm(x, lp["attn_norm"], cfg.norm)
    a, new_kv = attention(lp["attn"], h, cfg, positions=positions, cache=cache, cache_pos=cache_pos)
    # named so the "save_collectives" remat policy can pin the post-psum
    # tensors and avoid re-running the TP all-reduces in the backward pass
    x = x + checkpoint_name(a, "attn_out")
    h = apply_norm(x, lp["mlp_norm"], cfg.norm)
    x = x + checkpoint_name(swiglu(lp["mlp"], h), "mlp_out")
    x = shard_hint(x, ("batch", None, None))
    return x, new_kv


# ---------------------------------------------------------------------------
# full dense LM
# ---------------------------------------------------------------------------

def dense_lm_layout(cfg: ArchConfig) -> dict:
    return {
        **embed_params(cfg),
        "layers": stack_specs(dense_layer_params(cfg), cfg.n_layers),
    }


def remat_wrap(body, remat):
    """remat: False | True (full) | "save_collectives" (keep post-psum
    activations so the backward pass re-runs compute but not collectives)."""
    if not remat:
        return body
    if remat == "save_collectives":
        policy = jax.checkpoint_policies.save_only_these_names("attn_out", "mlp_out")
        return jax.checkpoint(body, policy=policy)
    return jax.checkpoint(body)


def dense_lm_forward(params: dict, tokens: jax.Array, cfg: ArchConfig, *, remat=False,
                     return_cache: bool = False):
    """Causal forward over full sequences (train / prefill).

    return_cache=True additionally returns per-layer stacked (k, v) of shape
    (L, B, T, KV, hd) for prefill->decode handoff.
    """
    x = embed_tokens(params, tokens, cfg)

    def body(x, lp):
        y, kv = dense_layer_apply(lp, x, cfg)
        return y, kv if return_cache else None

    fn = remat_wrap(body, remat)
    x, kvs = jax.lax.scan(fn, x, params["layers"])
    logits = unembed(params, x, cfg)
    if return_cache:
        return logits, kvs
    return logits


def write_cache(cache: KVCache, k_toks: jax.Array, v_toks: jax.Array, pos) -> KVCache:
    """Insert per-layer current-token k/v (L, B, 1, KV, hd) at position pos
    with ONE dynamic-update-slice per tensor (never loop-carried)."""
    nk = jax.lax.dynamic_update_slice(cache.k, k_toks.astype(cache.k.dtype), (0, 0, pos, 0, 0))
    nv = jax.lax.dynamic_update_slice(cache.v, v_toks.astype(cache.v.dtype), (0, 0, pos, 0, 0))
    return KVCache(nk, nv)


def dense_lm_decode(params: dict, token: jax.Array, cache: KVCache, pos, cfg: ArchConfig):
    """One decode step. token (B, 1) int32; cache (L, B, S, KV, hd) pair;
    pos scalar int32 current write index. Returns (logits (B,1,V), cache)."""
    x = embed_tokens(params, token, cfg)

    def body(x, inp):
        lp, ck, cv = inp
        y, (kc, vc) = dense_layer_apply(lp, x, cfg, cache=KVCache(ck, cv), cache_pos=pos)
        return y, (kc, vc)

    x, (kts, vts) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    logits = unembed(params, x, cfg)
    return logits, write_cache(cache, kts, vts, pos)
