"""Whisper-style encoder-decoder backbone (whisper-tiny).

Per the assignment the conv/mel frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, enc_seq, d). Sinusoidal positions
are added on the fly (supports arbitrary decoder lengths for the assigned
shape set even though released Whisper caps at 448). LayerNorm + GELU MLP +
biased MHA per the original architecture.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import KVCache, attention, attn_params, cross_attn_params, cross_attention
from .common import ParamSpec, apply_norm, make_norm_params
from .mlp import gelu_mlp, gelu_mlp_params
from .transformer import embed_params, embed_tokens, stack_specs, unembed

__all__ = ["encdec_layout", "encdec_encode", "encdec_forward", "encdec_decode", "EncDecCache", "encdec_init_cache", "sinusoidal"]


class EncDecCache(NamedTuple):
    self_kv: KVCache      # (L_dec, B, S, KV, hd)
    enc_out: jax.Array    # (B, T_enc, d)


def sinusoidal(T: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _enc_layer_params(cfg: ArchConfig) -> dict:
    return {
        "attn_norm": make_norm_params(cfg.d_model, cfg.norm),
        "attn": attn_params(cfg),
        "mlp_norm": make_norm_params(cfg.d_model, cfg.norm),
        "mlp": gelu_mlp_params(cfg.d_model, cfg.d_ff),
    }


def _dec_layer_params(cfg: ArchConfig) -> dict:
    p = _enc_layer_params(cfg)
    p["cross_norm"] = make_norm_params(cfg.d_model, cfg.norm)
    p["cross"] = attn_params(cfg)
    return p


def encdec_layout(cfg: ArchConfig) -> dict:
    return {
        **embed_params(cfg),
        "enc_layers": stack_specs(_enc_layer_params(cfg), cfg.n_enc_layers),
        "enc_norm": make_norm_params(cfg.d_model, cfg.norm),
        "dec_layers": stack_specs(_dec_layer_params(cfg), cfg.n_layers),
    }


def encdec_encode(params: dict, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames (B, T_enc, d) stubbed frontend output -> encoder states."""
    x = frames + sinusoidal(frames.shape[1], cfg.d_model, frames.dtype)[None]

    def body(x, lp):
        h = apply_norm(x, lp["attn_norm"], cfg.norm)
        a, _ = attention(lp["attn"], h, cfg, causal=False)
        x = x + a
        h = apply_norm(x, lp["mlp_norm"], cfg.norm)
        return x + gelu_mlp(lp["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(x, params["enc_norm"], cfg.norm)


def _dec_layer(lp, x, enc_out, cfg: ArchConfig, cache: KVCache | None = None, cache_pos=None):
    h = apply_norm(x, lp["attn_norm"], cfg.norm)
    a, kv = attention(lp["attn"], h, cfg, cache=cache, cache_pos=cache_pos)
    x = x + a
    h = apply_norm(x, lp["cross_norm"], cfg.norm)
    x = x + cross_attention(lp["cross"], h, enc_out, cfg)
    h = apply_norm(x, lp["mlp_norm"], cfg.norm)
    return x + gelu_mlp(lp["mlp"], h), kv


def encdec_forward(params: dict, tokens: jax.Array, frames: jax.Array, cfg: ArchConfig,
                   *, remat: bool = False, return_cache: bool = False):
    """Teacher-forced decode over full token sequence (train / prefill)."""
    enc_out = encdec_encode(params, frames, cfg)
    x = embed_tokens(params, tokens, cfg)
    x = x + sinusoidal(tokens.shape[1], cfg.d_model, x.dtype)[None]

    def body(x, lp):
        y, kv = _dec_layer(lp, x, enc_out, cfg)
        return y, kv if return_cache else None

    from .transformer import remat_wrap

    fn = remat_wrap(body, remat)
    x, kvs = jax.lax.scan(fn, x, params["dec_layers"])
    logits = unembed(params, x, cfg)
    if return_cache:
        return logits, (kvs, enc_out)
    return logits


def encdec_init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> EncDecCache:
    hd = cfg.head_dim_
    return EncDecCache(
        self_kv=KVCache(
            k=jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            v=jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd), dtype),
        ),
        enc_out=jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dtype),
    )


def encdec_decode(params: dict, token: jax.Array, cache: EncDecCache, pos, cfg: ArchConfig):
    x = embed_tokens(params, token, cfg)
    # position-dependent embedding for the current step
    half = sinusoidal_at(pos, cfg.d_model, x.dtype)
    x = x + half[None, None, :]

    def body(x, inp):
        lp, ck, cv = inp
        y, (kc, vc) = _dec_layer(lp, x, cache.enc_out, cfg, cache=KVCache(ck, cv), cache_pos=pos)
        return y, (kc, vc)

    x, (kts, vts) = jax.lax.scan(body, x, (params["dec_layers"], cache.self_kv.k, cache.self_kv.v))
    logits = unembed(params, x, cfg)
    from .transformer import write_cache

    return logits, EncDecCache(self_kv=write_cache(cache.self_kv, kts, vts, pos), enc_out=cache.enc_out)


def sinusoidal_at(pos, d: int, dtype) -> jax.Array:
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
