"""MoE decoder LM (granite-moe-1b-a400m: 32e top-8; olmoe-1b-7b: 64e top-8).

Attention stack identical to the dense family; every layer's FFN is the
capacity-bounded top-k MoE from models/moe.py. The auxiliary load-balance
loss is summed across layers and returned alongside the logits.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import KVCache, attention, attn_params
from .common import apply_norm, make_norm_params
from .moe import moe_ffn, moe_params
from .transformer import embed_params, embed_tokens, stack_specs, unembed

__all__ = ["moe_lm_layout", "moe_lm_forward", "moe_lm_decode"]


def _moe_layer_params(cfg: ArchConfig) -> dict:
    return {
        "attn_norm": make_norm_params(cfg.d_model, cfg.norm),
        "attn": attn_params(cfg),
        "mlp_norm": make_norm_params(cfg.d_model, cfg.norm),
        "moe": moe_params(cfg.d_model, cfg.d_ff, cfg.n_experts),
    }


def moe_lm_layout(cfg: ArchConfig) -> dict:
    return {
        **embed_params(cfg),
        "layers": stack_specs(_moe_layer_params(cfg), cfg.n_layers),
    }


def _moe_layer_apply(lp, x, cfg: ArchConfig, *, cache: Optional[KVCache] = None, cache_pos=None):
    from .common import current_mesh
    from .moe import moe_ffn_sharded

    h = apply_norm(x, lp["attn_norm"], cfg.norm)
    a, new_kv = attention(lp["attn"], h, cfg, cache=cache, cache_pos=cache_pos)
    x = x + a
    h = apply_norm(x, lp["mlp_norm"], cfg.norm)
    B, T, d = h.shape
    mesh = current_mesh()
    use_sharded = (
        mesh is not None
        and "model" in mesh.shape
        and cfg.n_experts % mesh.shape["model"] == 0
        and all(B % mesh.shape[a] == 0 for a in ("pod", "data") if a in mesh.shape)
    )
    if use_sharded:
        y3, aux = moe_ffn_sharded(lp["moe"], h, cfg.top_k, cfg.moe_capacity_factor)
        x = x + y3
    else:
        y, aux = moe_ffn(lp["moe"], h.reshape(B * T, d), cfg.top_k, cfg.moe_capacity_factor)
        x = x + y.reshape(B, T, d)
    return x, new_kv, aux


def moe_lm_forward(params: dict, tokens: jax.Array, cfg: ArchConfig, *, remat: bool = False,
                   return_cache: bool = False):
    """Returns (logits, aux_loss) or (logits, aux_loss, kvs)."""
    x = embed_tokens(params, tokens, cfg)

    def body(carry, lp):
        x, aux_sum = carry
        y, kv, aux = _moe_layer_apply(lp, x, cfg)
        return (y, aux_sum + aux), kv if return_cache else None

    from .transformer import remat_wrap

    fn = remat_wrap(body, remat)
    (x, aux), kvs = jax.lax.scan(fn, (x, jnp.float32(0.0)), params["layers"])
    logits = unembed(params, x, cfg)
    if return_cache:
        return logits, aux, kvs
    return logits, aux


def moe_lm_decode(params: dict, token: jax.Array, cache: KVCache, pos, cfg: ArchConfig):
    from .transformer import write_cache

    x = embed_tokens(params, token, cfg)

    def body(x, inp):
        lp, ck, cv = inp
        y, (kc, vc), _aux = _moe_layer_apply(lp, x, cfg, cache=KVCache(ck, cv), cache_pos=pos)
        return y, (kc, vc)

    x, (kts, vts) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    return unembed(params, x, cfg), write_cache(cache, kts, vts, pos)
