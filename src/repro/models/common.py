"""Shared model-building blocks: param layout, norms, RoPE, sharding hints.

Parameter single-source-of-truth: every family declares its weights as a
tree of ``ParamSpec(shape, logical_axes, init)``. From that one tree we
derive (a) materialized params, (b) abstract ShapeDtypeStructs for the
dry-run, (c) NamedSharding specs via the launch-layer logical-axis rules.

Sharding hints: models call ``shard_hint(x, axes)`` on activations; outside
a mesh context it is a no-op, under ``use_sharding_rules`` it becomes
``with_sharding_constraint`` with divisibility-checked specs (see
launch/sharding.py).
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "materialize",
    "abstract",
    "logical_axes_tree",
    "shard_hint",
    "use_sharding_rules",
    "rmsnorm",
    "layernorm",
    "make_norm_params",
    "apply_rope",
    "rope_angles",
    "causal_mask_bias",
    "DTYPES",
]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | normal_out (scaled by fan-out axis -1)
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_array(key, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.full(spec.shape, spec.scale, dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def materialize(key: jax.Array, tree, dtype) -> dict:
    """ParamSpec tree -> array tree (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_array(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def abstract(tree, dtype) -> dict:
    """ParamSpec tree -> ShapeDtypeStruct tree (no allocation; dry-run)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def logical_axes_tree(tree) -> dict:
    return jax.tree.map(lambda s: s.axes, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# --------------------------------------------------------------------------
# sharding-hint context (installed by launch/sharding.py)
# --------------------------------------------------------------------------

_ACTIVE_RULES: contextvars.ContextVar = contextvars.ContextVar("repro_sharding_rules", default=None)
_ACTIVE_MESH: contextvars.ContextVar = contextvars.ContextVar("repro_active_mesh", default=None)


@contextlib.contextmanager
def use_sharding_rules(resolver: Callable, mesh=None):
    """resolver(shape, logical_axes) -> NamedSharding | None.

    ``mesh`` (optional) additionally exposes the active device mesh to
    modules that build explicit shard_map regions (the sharded MoE
    dispatch) via ``current_mesh()``.
    """
    token = _ACTIVE_RULES.set(resolver)
    token_m = _ACTIVE_MESH.set(mesh)
    try:
        yield
    finally:
        _ACTIVE_RULES.reset(token)
        _ACTIVE_MESH.reset(token_m)


def current_mesh():
    return _ACTIVE_MESH.get()


def shard_hint(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    resolver = _ACTIVE_RULES.get()
    if resolver is None:
        return x
    sharding = resolver(x.shape, axes)
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def make_norm_params(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamSpec((d,), ("embed",), init="ones")}
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def apply_norm(x, params, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,) int -> cos/sin of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., seq, heads, head_dim); cos/sin (seq, head_dim//2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast cos/sin over the heads axis
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1).astype(x.dtype)


def causal_mask_bias(q_len: int, kv_len: int, q_offset=0, dtype=jnp.float32) -> jax.Array:
    """(q_len, kv_len) additive bias: 0 where kv <= q_offset + q, -inf after."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return jnp.where(kv_pos <= q_pos, 0.0, -1e30).astype(dtype)
