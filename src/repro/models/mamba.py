"""Mamba-2 (SSD) blocks and the Zamba2 hybrid LM.

Mamba-2's SSD layer is scalar-decay linear attention: per-head decay
a_t = exp(-softplus(dt_t) * exp(A_log)) and input scale dt_t, with shared
B/C projections playing k/q — mapped onto the chunked GLA engine. A short
causal depthwise conv precedes the SSM input (kernel 4), with a conv-tail
cache for decode.

Zamba2 (cfg.attn_every=k): groups of k Mamba-2 blocks followed by ONE
shared full-attention transformer block (weights reused by every group —
Zamba2's parameter-sharing design; per-invocation LoRA deltas omitted, see
DESIGN.md §9). ``long_500k`` decode attends over the shared block's KV
cache, sharded over the data axis (context parallel).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import KVCache, attention, attn_params
from .common import ParamSpec, apply_norm, make_norm_params, rmsnorm
from .gla import GLAState, gla_chunked, gla_init_state, gla_step
from .mlp import swiglu, swiglu_params
from .transformer import embed_params, embed_tokens, stack_specs, unembed

__all__ = [
    "ZambaState",
    "mamba_block_params",
    "zamba_layout",
    "zamba_forward",
    "zamba_decode",
    "zamba_init_state",
]

_CONV_K = 4


class ZambaState(NamedTuple):
    ssm: GLAState        # stacked (L_mamba, B, H, dk, dv)
    conv: jax.Array      # (L_mamba, B, _CONV_K-1, conv_channels)
    attn_kv: KVCache     # (n_groups, B, S, KV, hd) — shared-block caches
    pos: jax.Array       # scalar int32


def mamba_block_params(cfg: ArchConfig) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    nh = cfg.ssm_heads_
    st = cfg.ssm_state
    conv_ch = din + 2 * st  # x, B, C go through the conv
    return {
        "norm": make_norm_params(d, cfg.norm),
        "w_in": ParamSpec((d, 2 * din + 2 * st + nh), ("embed", "mlp")),
        "conv_w": ParamSpec((_CONV_K, conv_ch), (None, "mlp"), scale=0.5),
        "A_log": ParamSpec((nh,), (None,), init="zeros"),
        "D": ParamSpec((nh,), (None,), init="ones"),
        "dt_bias": ParamSpec((nh,), (None,), init="zeros"),
        "out_norm": {"scale": ParamSpec((din,), ("mlp",), init="ones")},
        "w_out": ParamSpec((din, d), ("mlp", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, tail: jax.Array | None):
    """Depthwise causal conv, kernel K. x (B,T,C); w (K,C); tail (B,K-1,C)
    carries the previous K-1 inputs for decode. Returns (y, new_tail)."""
    B, T, C = x.shape
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, K - 1, C), x.dtype)
    xt = jnp.concatenate([tail, x], axis=1)  # (B, T+K-1, C)
    y = jnp.zeros_like(x)
    for i in range(K):
        y = y + xt[:, i : i + T, :] * w[i]
    new_tail = xt[:, -(K - 1) :, :]
    return y, new_tail


def mamba_apply(lp, x, cfg: ArchConfig, state: GLAState | None, conv_tail, *, step: bool):
    B, T, d = x.shape
    din = cfg.d_inner
    nh = cfg.ssm_heads_
    stt = cfg.ssm_state
    dh = din // nh

    h = apply_norm(x, lp["norm"], cfg.norm)
    proj = h @ lp["w_in"]
    z, xbc, dt_raw = jnp.split(proj, [din, 2 * din + 2 * stt], axis=-1)
    xbc, new_tail = _causal_conv(xbc, lp["conv_w"], conv_tail)
    xbc = jax.nn.silu(xbc)
    xs, Bp, Cp = jnp.split(xbc, [din, din + stt], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])  # (B,T,nh)
    log_a = -jnp.exp(lp["A_log"].astype(jnp.float32)) * dt            # (B,T,nh)

    # q=C, k=B shared across heads; v = x (per head), input gate b=dt
    q = jnp.broadcast_to(Cp[:, :, None, :], (B, T, nh, stt))
    k = jnp.broadcast_to(Bp[:, :, None, :], (B, T, nh, stt))
    v = xs.reshape(B, T, nh, dh)
    if step:
        y, new_state = gla_step(q[:, 0], k[:, 0], v[:, 0], log_a[:, 0], dt[:, 0], state)
        y = y[:, None]
    else:
        y, new_state = gla_chunked(q, k, v, log_a, dt, cfg.chunk, state=state)
    y = y + v * lp["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, T, din)
    y = rmsnorm(y * jax.nn.silu(z), lp["out_norm"]["scale"])
    return x + y @ lp["w_out"], new_state, new_tail


def _shared_block_params(cfg: ArchConfig) -> dict:
    return {
        "attn_norm": make_norm_params(cfg.d_model, cfg.norm),
        "attn": attn_params(cfg),
        "mlp_norm": make_norm_params(cfg.d_model, cfg.norm),
        "mlp": swiglu_params(cfg.d_model, cfg.d_ff),
    }


def zamba_layout(cfg: ArchConfig) -> dict:
    n_groups = cfg.n_layers // cfg.attn_every
    n_mamba = cfg.n_layers - n_groups  # k-1 mamba per group... see forward
    # interpretation: n_layers counts mamba blocks; the shared attn block is
    # applied after every ``attn_every`` of them (9 applications for 54/6).
    del n_mamba
    return {
        **embed_params(cfg),
        "mamba": stack_specs(mamba_block_params(cfg), cfg.n_layers),
        "shared_attn": _shared_block_params(cfg),  # ONE set of weights
    }


def _shared_block_apply(sp, x, cfg: ArchConfig, *, cache=None, cache_pos=None):
    h = apply_norm(x, sp["attn_norm"], cfg.norm)
    a, kv = attention(sp["attn"], h, cfg, cache=cache, cache_pos=cache_pos)
    x = x + a
    h = apply_norm(x, sp["mlp_norm"], cfg.norm)
    return x + swiglu(sp["mlp"], h), kv


def zamba_forward(params: dict, tokens: jax.Array, cfg: ArchConfig, *, remat: bool = False,
                  return_state: bool = False):
    x = embed_tokens(params, tokens, cfg)
    k = cfg.attn_every
    n_groups = cfg.n_layers // k

    def m_tree(g):
        return jax.tree.map(lambda a: a.reshape(n_groups, k, *a.shape[1:])[g], params["mamba"])

    ssm_states, conv_tails, attn_kvs = [], [], []
    for g in range(n_groups):
        def body(x, lp):
            y, st, tail = mamba_apply(lp, x, cfg, None, None, step=False)
            return y, (st, tail)

        from .transformer import remat_wrap

        fn = remat_wrap(body, remat)
        x, (sts, tails) = jax.lax.scan(fn, x, m_tree(g))
        x, kv = _shared_block_apply(params["shared_attn"], x, cfg)
        ssm_states.append(sts)
        conv_tails.append(tails)
        attn_kvs.append(kv)

    logits = unembed(params, x, cfg)
    if return_state:
        state = ZambaState(
            ssm=GLAState(
                S=jnp.concatenate([s.S for s in ssm_states], axis=0),
                n=jnp.concatenate([s.n for s in ssm_states], axis=0),
            ),
            conv=jnp.concatenate(conv_tails, axis=0),
            attn_kv=KVCache(
                k=jnp.stack([kv[0] for kv in attn_kvs]),
                v=jnp.stack([kv[1] for kv in attn_kvs]),
            ),
            pos=jnp.int32(tokens.shape[1]),
        )
        return logits, state
    return logits


def zamba_init_state(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> ZambaState:
    nh = cfg.ssm_heads_
    din = cfg.d_inner
    dh = din // nh
    stt = cfg.ssm_state
    k = cfg.attn_every
    n_groups = cfg.n_layers // k
    L = cfg.n_layers
    conv_ch = din + 2 * stt
    return ZambaState(
        ssm=GLAState(
            S=jnp.zeros((L, batch, nh, stt, dh), jnp.float32),
            n=jnp.zeros((L, batch, nh, stt), jnp.float32),
        ),
        conv=jnp.zeros((L, batch, _CONV_K - 1, conv_ch), dtype),
        attn_kv=KVCache(
            k=jnp.zeros((n_groups, batch, max_seq, cfg.n_kv_heads, cfg.head_dim_), dtype),
            v=jnp.zeros((n_groups, batch, max_seq, cfg.n_kv_heads, cfg.head_dim_), dtype),
        ),
        pos=jnp.int32(0),
    )


def zamba_decode(params: dict, token: jax.Array, state: ZambaState, pos, cfg: ArchConfig):
    x = embed_tokens(params, token, cfg)
    k = cfg.attn_every
    n_groups = cfg.n_layers // k

    new_S, new_n, new_tails = [], [], []
    new_k, new_v = [], []
    for g in range(n_groups):
        for j in range(k):
            li = g * k + j
            lp = jax.tree.map(lambda a: a[li], params["mamba"])
            st = GLAState(S=state.ssm.S[li], n=state.ssm.n[li])
            x, st2, tail = mamba_apply(lp, x, cfg, st, state.conv[li], step=True)
            new_S.append(st2.S)
            new_n.append(st2.n)
            new_tails.append(tail)
        cache = KVCache(k=state.attn_kv.k[g], v=state.attn_kv.v[g])
        x, (kc, vc) = _shared_block_apply(params["shared_attn"], x, cfg, cache=cache, cache_pos=pos)
        new_k.append(kc)
        new_v.append(vc)

    logits = unembed(params, x, cfg)
    from .transformer import write_cache

    new_state = ZambaState(
        ssm=GLAState(S=jnp.stack(new_S), n=jnp.stack(new_n)),
        conv=jnp.stack(new_tails),
        attn_kv=write_cache(state.attn_kv, jnp.stack(new_k), jnp.stack(new_v), pos),
        pos=pos + 1,
    )
    return logits, new_state
