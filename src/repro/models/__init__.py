"""Model zoo: the 10 assigned architectures across 6 families."""
from .zoo import ModelApi, build_model

__all__ = ["ModelApi", "build_model"]
