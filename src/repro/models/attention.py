"""GQA attention (train / prefill / cached decode) + cross-attention.

Weights are kept in fused (d_model, n_heads*head_dim) form so tensor-
parallel sharding applies to the flat feature axis — this keeps archs whose
head counts don't divide the model axis (qwen2.5: 40 heads, whisper: 6)
shardable without padding (DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ParamSpec, apply_rope, causal_mask_bias, rmsnorm, rope_angles, shard_hint

__all__ = ["attn_params", "cross_attn_params", "attention", "cross_attention", "KVCache", "init_kv_cache"]


class KVCache(NamedTuple):
    k: jax.Array  # (B, S, n_kv, hd)
    v: jax.Array  # (B, S, n_kv, hd)


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, n_layers: int, dtype) -> KVCache:
    hd = cfg.head_dim_
    shape = (n_layers, batch, max_seq, cfg.n_kv_heads, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def attn_params(cfg: ArchConfig, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.head_dim_
    qd, kvd = cfg.n_heads * hd, cfg.n_kv_heads * hd
    p = {
        "wq": ParamSpec((d, qd), ("embed", "heads_flat")),
        "wk": ParamSpec((d, kvd), ("embed", "kv_flat")),
        "wv": ParamSpec((d, kvd), ("embed", "kv_flat")),
        "wo": ParamSpec((qd, d), ("heads_flat", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((qd,), ("heads_flat",), init="zeros")
        p["bk"] = ParamSpec((kvd,), ("kv_flat",), init="zeros")
        p["bv"] = ParamSpec((kvd,), ("kv_flat",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        p["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return p


def cross_attn_params(cfg: ArchConfig) -> dict:
    p = attn_params(cfg)
    p["gate"] = ParamSpec((1,), (None,), init="zeros")  # llama-vision tanh gate
    return p


def _project_qkv(p, x, cfg: ArchConfig, kv_src=None):
    hd = cfg.head_dim_
    kv_in = x if kv_src is None else kv_src
    q = x @ p["wq"]
    k = kv_in @ p["wk"]
    v = kv_in @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, Tq = q.shape[0], q.shape[1]
    Tk = k.shape[1]
    q = q.reshape(B, Tq, cfg.n_heads, hd)
    k = k.reshape(B, Tk, cfg.n_kv_heads, hd)
    v = v.reshape(B, Tk, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    return q, k, v


def _sdpa(q, k, v, bias: Optional[jax.Array], n_rep: int):
    """q (B,Tq,H,hd), k/v (B,Tk,KV,hd); returns (B,Tq,H,hd)."""
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    qg = q.reshape(B, Tq, KV, n_rep, hd)
    scores = jnp.einsum("bqgrh,bkgh->bgrqk", qg, k, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if bias is not None:
        scores = scores + bias  # broadcast (.., Tq, Tk)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", w, v)
    return out.reshape(B, Tq, H, hd)


def _sdpa_blocked(q, k, v, n_rep: int, q_tile: int):
    """Blocked-causal attention: static loop over Q tiles, each attending
    only to its KV prefix, with bf16 score storage.

    Perf-iteration lesson (EXPERIMENTS.md §Perf): a scan-based online
    softmax REGRESSED HBM traffic because the (Tq, hd) accumulator becomes
    a loop-carried HBM buffer re-read per chunk. This version has no loop
    carries — each Q tile is an independent dataflow island — and wins by
    (a) skipping the strictly-upper-triangular score blocks (~2x) and
    (b) storing probabilities in the compute dtype instead of f32 (~2x).
    The full single-pass fix is the Pallas flash kernel
    (repro.kernels.flash_attn), which applies on the real TPU target.
    """
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    assert Tq % q_tile == 0, (Tq, q_tile)
    f32 = jnp.float32
    qg = q.reshape(B, Tq, KV, n_rep, hd)
    outs = []
    for i in range(Tq // q_tile):
        hi = (i + 1) * q_tile
        qt = qg[:, i * q_tile : hi]
        kt, vt = k[:, :hi], v[:, :hi]
        s = jnp.einsum("bqgrh,bkgh->bgrqk", qt, kt, preferred_element_type=f32)
        s = s / jnp.sqrt(hd).astype(f32)
        q_pos = i * q_tile + jnp.arange(q_tile)
        s = s + jnp.where(jnp.arange(hi)[None, :] <= q_pos[:, None], 0.0, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)  # bf16 storage
        o = jnp.einsum("bgrqk,bkgh->bqgrh", p, vt)
        outs.append(o.reshape(B, q_tile, H, hd))
    return jnp.concatenate(outs, axis=1)


def _sdpa_decode(q, k_cur, v_cur, cache: KVCache, cache_pos, n_rep: int):
    """One-token attention over a read-only cache + the current token.

    q (B,1,H,hd); k_cur/v_cur (B,1,KV,hd); cache.k/.v (B,S,KV,hd).
    Joint softmax over [cache[<pos], current]."""
    B, _, H, hd = q.shape
    S, KV = cache.k.shape[1], cache.k.shape[2]
    f32 = jnp.float32
    qg = q.reshape(B, 1, KV, n_rep, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(f32)
    s_c = jnp.einsum("bqgrh,bkgh->bgrqk", qg, cache.k, preferred_element_type=f32) * scale
    kv_pos = jnp.arange(S)
    s_c = s_c + jnp.where(kv_pos < cache_pos, 0.0, -1e30)  # strictly past
    s_s = jnp.einsum("bqgrh,bqgh->bgrq", qg, k_cur, preferred_element_type=f32) * scale
    m = jnp.maximum(s_c.max(axis=-1), s_s)  # (B,KV,rep,1)
    p_c = jnp.exp(s_c - m[..., None])
    p_s = jnp.exp(s_s - m)
    denom = p_c.sum(axis=-1) + p_s
    out = jnp.einsum("bgrqk,bkgh->bqgrh", (p_c / denom[..., None]).astype(q.dtype), cache.v)
    out = out + (p_s / denom).astype(q.dtype).transpose(0, 3, 1, 2)[..., None] * v_cur.reshape(
        B, 1, KV, 1, hd
    )
    return out.reshape(B, 1, H, hd)


def attention(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: jax.Array | None = None,
    cache: Optional[KVCache] = None,
    cache_pos: jax.Array | None = None,
    causal: bool = True,
):
    """Self-attention.

    Train/prefill: cache=None -> full causal pass; returns (out, (k, v)).
    Decode: cache=(k,v) of length S; x is (B, 1, d); cache_pos scalar write
    index; returns (out, updated (k, v)).
    """
    B, T, d = x.shape
    hd = cfg.head_dim_
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _project_qkv(p, x, cfg)

    if positions is None:
        if cache is None:
            positions = jnp.arange(T)[None, :]
        else:
            positions = jnp.broadcast_to(jnp.asarray(cache_pos)[None, None], (B, 1))
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None:
        q = shard_hint(q, ("batch", None, "heads", None))
        if cfg.attn_chunk > 0 and causal and T % cfg.attn_chunk == 0 and T > cfg.attn_chunk:
            out = _sdpa_blocked(q, k, v, n_rep, cfg.attn_chunk)
        else:
            bias = causal_mask_bias(T, T) if causal else None
            out = _sdpa(q, k, v, bias, n_rep)
        new_kv = (k, v)
    else:
        # READ-ONLY cache attention: attend over cache[< pos] plus the
        # current token as an explicit extra column. The cache write is the
        # caller's job (one small dynamic-update-slice for ALL layers after
        # the layer scan) — updating inside the scan makes the whole stacked
        # cache a loop-carried buffer that XLA copies/converts per layer
        # (the 0.65s -> measured memory blow-up in EXPERIMENTS.md §Perf).
        out = _sdpa_decode(q, k, v, cache, cache_pos, n_rep)
        new_kv = (k, v)  # (B, 1, KV, hd) current-token tensors

    out = out.reshape(B, T, cfg.n_heads * hd)
    return out @ p["wo"], new_kv


def cross_attention(p: dict, x: jax.Array, kv_feats: jax.Array, cfg: ArchConfig, gated: bool = False):
    """Cross-attention: queries from x (B,T,d), keys/values from kv_feats
    (B,S,d). No RoPE, no causality (encoder side is fully visible)."""
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _project_qkv(p, x, cfg, kv_src=kv_feats)
    out = _sdpa(q, k, v, None, n_rep)
    B, T = x.shape[0], x.shape[1]
    out = out.reshape(B, T, cfg.n_heads * cfg.head_dim_) @ p["wo"]
    if gated:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return out
