"""Top-level solver API — one-shot ``solve`` over the plan/execute split.

``repro.plan(A, ...)`` is the primary entry point: it pays the setup cost
(preconditioner, perf-model decomposition, mesh + ``ShardedDIA`` handle,
jit trace of the iteration loop) exactly once and returns a reusable
``SolverPlan`` (see ``repro.plan``'s module docstring).

``repro.solve(A, b, method=..., engine=...)`` is the one-shot convenience
form: a thin wrapper that fetches the matching plan from a keyed LRU cache
(operator identity x method/engine/shards/weights/... configuration) and
runs ``plan.solve(b)``. Repeated solves against the same operator and
configuration therefore reuse the compiled loop and the sharded operator
handle — serving-loop economics without holding a plan handle.

    method                          runs
    -----------------------------   --------------------------------------
    "pcg"                           Algorithm 1 baseline (3 blocking dots)
    "chronopoulos"                  merged single reduction, no overlap
    "pipecg"                        Algorithm 2, single device
    "pipecg_distributed" / "h1" /   shard_map over ``shards`` devices with
    "h2" / "h3"                     the named hybrid schedule (default h3)
    "h4"                            hierarchical two-stage reduction on a
                                    2-D (pod, sub) mesh (pass ``sub=``)
    "pl2" / "pl3"                   depth-l pipelined CG: ONE global
                                    reduction per l iterations (pass
                                    ``replace_every=`` — recommended)

    Distributed method x reducer selection matrix, reductions-per-
    iteration table and residual-replacement guidance: docs/distributed.md.

``engine`` selects the iteration-core backend: "jnp" (reference),
"pallas" (fused VMA+dots kernel, SPMV separate), "fused_iter" (the whole
PIPECG iteration — banded SPMV + Jacobi/identity PC + 8 VMAs + 3 dot
partials — as ONE Pallas kernel; DIAMatrix only), or "auto" (fused_iter
on TPU when eligible, else pallas on TPU, jnp elsewhere). ``spmv_engine``
independently picks the SPMV backend ("jnp"/"pallas"/"segsum"/"bf16"/
"auto"); "bf16" streams band data at half precision with f32 accumulation
and turns on residual replacement by default. ``M`` may be a
preconditioner object, the string "jacobi" (default) or None/"identity".
``A`` may be any ``LinearOperator`` — materialized (``DIAMatrix``/
``BellMatrix``/``CSRMatrix``/dense) or matrix-free
(``repro.sparse.FunctionOperator``) — for the non-distributed methods.

The registry is open: ``register_solver`` adds new (jit-traceable) methods
without touching call sites — ``launch/solve.py``,
``serve.engine.SolverEngine``, the benchmarks and the examples all go
through plans.

For live traffic, the async serving tier (``repro.serve.SolverServer``:
admission queue with backpressure, plan-pool router, cross-process
warm-start manifests) wraps this same plan cache — see docs/serving.md.
"""
from __future__ import annotations

from .core.types import SolveResult
from .plan import (  # noqa: F401  (re-exported registry surface)
    SolverPlan,
    clear_plan_cache,
    get_plan,
    plan,
    plan_cache_stats,
    register_solver,
    solver_names,
)

__all__ = [
    "solve",
    "plan",
    "SolverPlan",
    "register_solver",
    "solver_names",
    "plan_cache_stats",
    "clear_plan_cache",
]


def solve(
    A,
    b,
    method: str = "pipecg",
    engine: str = "auto",
    M="jacobi",
    x0=None,
    atol: float = 1e-5,
    rtol: float = 0.0,
    maxiter: int = 10000,
    **kwargs,
) -> SolveResult:
    """Solve SPD ``A x = b`` once; see module docstring for method/engine axes.

    Extra keyword arguments are forwarded to the method implementation —
    e.g. ``replace_every``/``spmv_engine``/``tile`` (pipecg),
    ``shards``/``weights``/``partition``/``mesh``/``reducer``/``spmv``/
    ``sub``/``replace_every`` (distributed methods — docs/distributed.md
    has the selection matrix). A keyword the method does not accept
    raises TypeError (nothing is silently dropped). Nonzero ``x0`` is
    supported everywhere — distributed methods solve the shifted system
    ``A d = b - A x0`` and return ``x0 + d``.

    Internally this is ``get_plan(...).solve(b, ...)``: plans are cached
    per (operator identity, configuration), so calling ``solve`` in a loop
    re-traces nothing after the first call. Hold an explicit
    ``repro.plan(...)`` handle when you want setup/teardown control or
    batched execution (``plan.solve_batched``).
    """
    p = get_plan(A, method=method, engine=engine, M=M, maxiter=maxiter, **kwargs)
    return p.solve(b, x0=x0, atol=atol, rtol=rtol)
