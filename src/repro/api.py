"""Top-level solver API: ``repro.solve(A, b, method=..., engine=...)``.

One entry point over every execution strategy of the same PIPECG math:

    method                          runs
    -----------------------------   --------------------------------------
    "pcg"                           Algorithm 1 baseline (3 blocking dots)
    "chronopoulos"                  merged single reduction, no overlap
    "pipecg"                        Algorithm 2, single device
    "pipecg_distributed" / "h1" /   shard_map over ``shards`` devices with
    "h2" / "h3"                     the named hybrid schedule (default h3)

``engine`` selects the kernel backend ("jnp", "pallas", "auto" = pallas on
TPU) for the iteration core and the SPMV dispatch. ``M`` may be a
preconditioner object, the string "jacobi" (default) or None/"identity".

The registry is open: ``register_solver`` adds new methods (e.g. future
deflated/communication-avoiding variants) without touching call sites —
``launch/solve.py``, ``serve.engine.SolverEngine``, the benchmarks and the
examples all go through ``solve``.
"""
from __future__ import annotations

import inspect
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .core import chronopoulos_cg, identity, jacobi, pcg, pipecg
from .core.distributed import make_solver_mesh, method_names, pipecg_distributed
from .core.perfmodel import decompose
from .core.preconditioners import IdentityPC, JacobiPC
from .core.types import SolveResult
from .sparse import DIAMatrix, balanced_rows, shard_dia, shard_vector, unshard_vector

__all__ = ["solve", "register_solver", "solver_names"]


def _resolve_pc(M, A):
    if M is None or M == "identity" or M == "none":
        return identity()
    if M == "jacobi":
        return jacobi(A)
    if isinstance(M, str):
        raise ValueError(f"unknown preconditioner name {M!r} (use 'jacobi'/'identity')")
    return M


def _require_jnp_engine(method: str, engine: str) -> None:
    # honest failure instead of silently running jnp under a "pallas" label
    if engine not in ("auto", "jnp"):
        raise ValueError(
            f"method {method!r} has no {engine!r} backend (the Pallas engines "
            "apply to pipecg and the distributed methods); use engine='jnp'/'auto'"
        )


def _solve_pcg(A, b, *, M, x0, atol, rtol, maxiter, engine):
    _require_jnp_engine("pcg", engine)
    return pcg(A, b, M=M, x0=x0, atol=atol, rtol=rtol, maxiter=maxiter)


def _solve_chronopoulos(A, b, *, M, x0, atol, rtol, maxiter, engine):
    _require_jnp_engine("chronopoulos", engine)
    return chronopoulos_cg(A, b, M=M, x0=x0, atol=atol, rtol=rtol, maxiter=maxiter)


def _solve_pipecg(A, b, *, M, x0, atol, rtol, maxiter, engine,
                  replace_every=0, spmv_engine=None):
    return pipecg(
        A, b, M=M, x0=x0, atol=atol, rtol=rtol, maxiter=maxiter,
        engine=engine, spmv_engine=spmv_engine, replace_every=replace_every,
    )


def _solve_distributed(
    A, b, *, M, x0, atol, rtol, maxiter, engine,
    dist_method="h3", shards=1, weights=None, partition="rows", mesh=None,
):
    if not isinstance(A, DIAMatrix):
        raise TypeError(f"distributed solve needs a DIAMatrix, got {type(A).__name__}")
    if x0 is not None and float(jnp.max(jnp.abs(x0))) != 0.0:
        raise ValueError("distributed solve supports x0=0 only")
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if len(jax.devices()) < shards:
        raise RuntimeError(
            f"need {shards} devices but only {len(jax.devices())} visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={shards} before importing jax"
        )
    if partition not in ("rows", "nnz"):
        raise ValueError(f"unknown partition {partition!r} (use 'rows' or 'nnz')")
    if weights is not None or partition == "nnz":
        bounds = decompose(A, shards, weights=None if weights is None else np.asarray(weights))
    else:
        bounds = balanced_rows(A.n, shards)
    if isinstance(M, JacobiPC):
        inv_diag = M.inv_diag
    elif isinstance(M, IdentityPC):
        inv_diag = jnp.ones((A.n,), b.dtype)
    else:
        raise TypeError(f"distributed solve supports Jacobi/identity PCs, got {type(M).__name__}")
    As = shard_dia(A, bounds)
    res = pipecg_distributed(
        As, shard_vector(b, bounds), shard_vector(inv_diag, bounds),
        mesh=mesh if mesh is not None else make_solver_mesh(shards),
        method=dist_method, engine=engine, atol=atol, rtol=rtol, maxiter=maxiter,
    )
    return SolveResult(
        x=unshard_vector(res.x, bounds),
        iterations=res.iterations,
        residual_norm=res.residual_norm,
        converged=res.converged,
        history=res.history,
    )


SolverFn = Callable[..., SolveResult]

_SOLVERS: Dict[str, SolverFn] = {
    "pcg": _solve_pcg,
    "chronopoulos": _solve_chronopoulos,
    "pipecg": _solve_pipecg,
    "pipecg_distributed": _solve_distributed,
}


def register_solver(name: str, fn: SolverFn) -> None:
    """Register a new solve method: ``fn(A, b, *, M, x0, ...) -> SolveResult``."""
    _SOLVERS[name] = fn


def solver_names() -> Tuple[str, ...]:
    return tuple(sorted(_SOLVERS)) + method_names()


def solve(
    A,
    b,
    method: str = "pipecg",
    engine: str = "auto",
    M="jacobi",
    x0=None,
    atol: float = 1e-5,
    rtol: float = 0.0,
    maxiter: int = 10000,
    **kwargs,
) -> SolveResult:
    """Solve SPD ``A x = b``; see module docstring for method/engine axes.

    Extra keyword arguments are forwarded to the method implementation —
    e.g. ``replace_every`` (pipecg), ``shards``/``weights``/``partition``/
    ``mesh`` (distributed methods). A keyword the method does not accept
    raises TypeError (nothing is silently dropped).
    """
    if method in method_names():  # "h1"/"h2"/"h3" aliases
        kwargs.setdefault("dist_method", method)
        method = "pipecg_distributed"
    if method not in _SOLVERS:
        raise ValueError(f"unknown method {method!r}; have {solver_names()}")
    fn = _SOLVERS[method]
    params = inspect.signature(fn).parameters
    if not any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        unknown = set(kwargs) - set(params)
        if unknown:
            raise TypeError(
                f"method {method!r} does not accept {sorted(unknown)}; "
                f"it takes {sorted(k for k in params if k not in ('A', 'b'))}"
            )
    return fn(
        A, b, M=_resolve_pc(M, A), x0=x0, atol=atol, rtol=rtol,
        maxiter=maxiter, engine=engine, **kwargs,
    )
